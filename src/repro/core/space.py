"""Discrete, constrained search spaces — the shared vocabulary of the suite.

A ``SearchSpace`` is an ordered set of named discrete parameters plus a list of
constraints (predicates over full configs).  This mirrors BAT 2.0's problem
interface: every benchmark exposes its tunable parameters and restrictions in
one declarative object that every tuner consumes unmodified.

Configs are plain ``dict[str, value]``.  For numeric work (surrogates, PFI)
configs can be encoded to index vectors and back.
"""

from __future__ import annotations

import itertools
import math
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator, Sequence

Config = dict[str, Any]


@dataclass(frozen=True)
class Param:
    """One tunable parameter: a name and its ordered list of discrete values."""

    name: str
    values: tuple

    def __post_init__(self) -> None:
        if len(self.values) == 0:
            raise ValueError(f"parameter {self.name!r} has no values")
        if len(set(self.values)) != len(self.values):
            raise ValueError(f"parameter {self.name!r} has duplicate values")

    @property
    def cardinality(self) -> int:
        return len(self.values)

    def index_of(self, value) -> int:
        return self.values.index(value)


@dataclass(frozen=True)
class Constraint:
    """A named predicate over full configs.  ``fn(config) -> bool``."""

    name: str
    fn: Callable[[Config], bool]

    def __call__(self, config: Config) -> bool:
        return bool(self.fn(config))


class SearchSpace:
    """An ordered, constrained, discrete configuration space.

    Provides: cardinality accounting (Table VIII), enumeration, uniform
    sampling via rejection, Hamming-1 neighborhoods (for local search and the
    fitness-flow graph), and index-vector encode/decode (for surrogates).
    """

    def __init__(self, params: Sequence[Param],
                 constraints: Sequence[Constraint] = (),
                 name: str = "space"):
        if len({p.name for p in params}) != len(params):
            raise ValueError("duplicate parameter names")
        self.name = name
        self.params: tuple[Param, ...] = tuple(params)
        self.constraints: tuple[Constraint, ...] = tuple(constraints)
        self._by_name = {p.name: p for p in self.params}

    # ------------------------------------------------------------------ #
    # basic accessors
    # ------------------------------------------------------------------ #
    @property
    def param_names(self) -> tuple[str, ...]:
        return tuple(p.name for p in self.params)

    def param(self, name: str) -> Param:
        return self._by_name[name]

    @property
    def cardinality(self) -> int:
        """Unconstrained cross-product size (Table VIII 'Cardinality')."""
        out = 1
        for p in self.params:
            out *= p.cardinality
        return out

    def satisfies(self, config: Config) -> bool:
        return all(c(config) for c in self.constraints)

    def violated(self, config: Config) -> list[str]:
        return [c.name for c in self.constraints if not c(config)]

    # ------------------------------------------------------------------ #
    # encode / decode
    # ------------------------------------------------------------------ #
    def encode(self, config: Config) -> tuple[int, ...]:
        """Config -> per-parameter value indices (surrogate features)."""
        return tuple(p.index_of(config[p.name]) for p in self.params)

    def decode(self, indices: Sequence[int]) -> Config:
        return {p.name: p.values[i] for p, i in zip(self.params, indices)}

    def flat_index(self, config: Config) -> int:
        """Config -> mixed-radix integer (stable unique id)."""
        idx = 0
        for p in self.params:
            idx = idx * p.cardinality + p.index_of(config[p.name])
        return idx

    def from_flat_index(self, idx: int) -> Config:
        out: Config = {}
        for p in reversed(self.params):
            idx, r = divmod(idx, p.cardinality)
            out[p.name] = p.values[r]
        return {p.name: out[p.name] for p in self.params}

    # ------------------------------------------------------------------ #
    # enumeration & sampling
    # ------------------------------------------------------------------ #
    def enumerate(self, constrained: bool = True) -> Iterator[Config]:
        for combo in itertools.product(*(p.values for p in self.params)):
            cfg = dict(zip(self.param_names, combo))
            if not constrained or self.satisfies(cfg):
                yield cfg

    def constrained_cardinality(self, limit: int | None = None) -> int:
        """Exact count of constraint-satisfying configs (Table VIII
        'Constrained').  ``limit`` caps the work for huge spaces."""
        n = 0
        for _ in self.enumerate(constrained=True):
            n += 1
            if limit is not None and n >= limit:
                return n
        return n

    def sample(self, rng: random.Random, max_tries: int = 10_000) -> Config:
        """Uniform sample from the *constrained* space via rejection."""
        for _ in range(max_tries):
            cfg = {p.name: rng.choice(p.values) for p in self.params}
            if self.satisfies(cfg):
                return cfg
        raise RuntimeError(
            f"{self.name}: could not sample a valid config in {max_tries} tries")

    def sample_batch(self, n: int, seed: int = 0) -> list[Config]:
        rng = random.Random(seed)
        return [self.sample(rng) for _ in range(n)]

    def sample_distinct(self, n: int, seed: int = 0,
                        max_tries_factor: int = 200) -> list[Config]:
        """Up to ``n`` distinct valid configs (the paper's 10 000-random-configs
        protocol)."""
        rng = random.Random(seed)
        seen: set[int] = set()
        out: list[Config] = []
        tries = 0
        while len(out) < n and tries < n * max_tries_factor:
            tries += 1
            cfg = self.sample(rng)
            key = self.flat_index(cfg)
            if key not in seen:
                seen.add(key)
                out.append(cfg)
        return out

    # ------------------------------------------------------------------ #
    # neighborhoods (local search, FFG/centrality)
    # ------------------------------------------------------------------ #
    def neighbors(self, config: Config, constrained: bool = True,
                  adjacent_only: bool = False) -> Iterator[Config]:
        """Hamming-distance-1 neighbors: change one parameter to another value.

        ``adjacent_only`` restricts moves to the next/previous value in the
        parameter's ordered list (the FFG in Schoonhoven et al. uses full
        Hamming-1; local search may prefer adjacent moves for numeric params).
        """
        for p in self.params:
            cur = config[p.name]
            i = p.index_of(cur)
            if adjacent_only:
                candidates = [j for j in (i - 1, i + 1) if 0 <= j < p.cardinality]
            else:
                candidates = [j for j in range(p.cardinality) if j != i]
            for j in candidates:
                cfg = dict(config)
                cfg[p.name] = p.values[j]
                if not constrained or self.satisfies(cfg):
                    yield cfg

    def random_neighbor(self, config: Config, rng: random.Random,
                        max_tries: int = 1000) -> Config:
        for _ in range(max_tries):
            p = rng.choice(self.params)
            v = rng.choice(p.values)
            if v == config[p.name]:
                continue
            cfg = dict(config)
            cfg[p.name] = v
            if self.satisfies(cfg):
                return cfg
        return dict(config)

    # ------------------------------------------------------------------ #
    # reductions (Table VIII 'Reduced')
    # ------------------------------------------------------------------ #
    def reduce(self, keep: Sequence[str], frozen: Config | None = None,
               name: str | None = None) -> "SearchSpace":
        """Project onto ``keep`` params; others frozen to ``frozen`` (default:
        first value).  Constraints are re-wrapped over the frozen context."""
        frozen = dict(frozen or {})
        for p in self.params:
            if p.name not in keep:
                frozen.setdefault(p.name, p.values[0])
        kept = [p for p in self.params if p.name in keep]

        def wrap(c: Constraint) -> Constraint:
            def fn(cfg: Config, _c=c) -> bool:
                full = dict(frozen)
                full.update(cfg)
                return _c(full)
            return Constraint(c.name, fn)

        return SearchSpace(kept, [wrap(c) for c in self.constraints],
                           name=name or f"{self.name}-reduced")

    def __repr__(self) -> str:  # pragma: no cover
        return (f"SearchSpace({self.name!r}, {len(self.params)} params, "
                f"|S|={self.cardinality}, {len(self.constraints)} constraints)")


def powers_of_two(lo: int, hi: int) -> tuple[int, ...]:
    """Inclusive powers of two between lo and hi."""
    out = []
    v = 1
    while v <= hi:
        if v >= lo:
            out.append(v)
        v *= 2
    return tuple(out)


def divisors(n: int) -> tuple[int, ...]:
    out = [d for d in range(1, n + 1) if n % d == 0]
    return tuple(out)


def multiples(step: int, lo: int, hi: int) -> tuple[int, ...]:
    return tuple(range(lo - lo % step + (step if lo % step else 0), hi + 1, step)) \
        if lo % step else tuple(range(lo, hi + 1, step))
