"""Discrete, constrained search spaces — the shared vocabulary of the suite.

A ``SearchSpace`` is an ordered set of named discrete parameters plus a list of
constraints (predicates over full configs).  This mirrors BAT 2.0's problem
interface: every benchmark exposes its tunable parameters and restrictions in
one declarative object that every tuner consumes unmodified.

Configs are plain ``dict[str, value]``.  For numeric work (surrogates, PFI)
configs can be encoded to index vectors and back.
"""

from __future__ import annotations

import itertools
import random
import threading
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Callable, Iterator, Sequence

if TYPE_CHECKING:  # pragma: no cover
    import numpy as np

    from .spacetable import CompiledSpace

Config = dict[str, Any]


@dataclass(frozen=True)
class Param:
    """One tunable parameter: a name and its ordered list of discrete values."""

    name: str
    values: tuple

    def __post_init__(self) -> None:
        if len(self.values) == 0:
            raise ValueError(f"parameter {self.name!r} has no values")
        if len(set(self.values)) != len(self.values):
            raise ValueError(f"parameter {self.name!r} has duplicate values")
        # value -> index lookup; every encode/flat_index everywhere hits this
        try:
            index = {v: i for i, v in enumerate(self.values)}
        except TypeError:             # unhashable values: linear fallback
            index = None
        object.__setattr__(self, "_index", index)

    @property
    def cardinality(self) -> int:
        return len(self.values)

    def index_of(self, value) -> int:
        if self._index is None:
            return self.values.index(value)
        try:
            return self._index[value]
        except KeyError:
            raise ValueError(
                f"{value!r} is not a value of parameter {self.name!r}") \
                from None
        except TypeError:             # unhashable query: linear fallback
            return self.values.index(value)


@dataclass(frozen=True)
class Constraint:
    """A named predicate over full configs.  ``fn(config) -> bool``.

    ``vec`` is the optional vectorized form used by
    :class:`~repro.core.spacetable.CompiledSpace`: it receives a dict of
    per-parameter *value* column arrays covering the whole cross product and
    returns a boolean array over rows.  It must be a total function (it is
    evaluated on every row, not only rows that passed earlier constraints)
    and must agree elementwise with ``fn``.
    """

    name: str
    fn: Callable[[Config], bool]
    vec: Callable[[dict], "np.ndarray"] | None = None

    def __call__(self, config: Config) -> bool:
        return bool(self.fn(config))


class SearchSpace:
    """An ordered, constrained, discrete configuration space.

    Provides: cardinality accounting (Table VIII), enumeration, uniform
    sampling via rejection, Hamming-1 neighborhoods (for local search and the
    fitness-flow graph), and index-vector encode/decode (for surrogates).
    """

    def __init__(self, params: Sequence[Param],
                 constraints: Sequence[Constraint] = (),
                 name: str = "space"):
        if len({p.name for p in params}) != len(params):
            raise ValueError("duplicate parameter names")
        self.name = name
        self.params: tuple[Param, ...] = tuple(params)
        self.constraints: tuple[Constraint, ...] = tuple(constraints)
        self._by_name = {p.name: p for p in self.params}
        self._compiled: "CompiledSpace | None" = None
        self._compile_lock = threading.Lock()

    # the compiled table and its lock are per-process derived state; drop
    # them when the space crosses a pickle boundary (process worker pools)
    def __getstate__(self) -> dict:
        state = self.__dict__.copy()
        state["_compiled"] = None
        state["_compile_lock"] = None
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._compile_lock = threading.Lock()

    # ------------------------------------------------------------------ #
    # compiled fast path
    # ------------------------------------------------------------------ #
    def compiled(self, limit: int | None = None,
                 build: bool = True) -> "CompiledSpace | None":
        """The :class:`~repro.core.spacetable.CompiledSpace` for this space,
        built lazily and cached (``None`` when the cross product exceeds
        ``limit``, default ``spacetable.DEFAULT_COMPILE_LIMIT``).  Compiled
        paths are exact drop-ins: identical configs, orders and draws as the
        iterator paths."""
        if self._compiled is not None:
            return self._compiled
        if not build:
            return None
        from .spacetable import DEFAULT_COMPILE_LIMIT, CompiledSpace
        lim = DEFAULT_COMPILE_LIMIT if limit is None else limit
        if self.cardinality > lim:
            return None
        with self._compile_lock:
            if self._compiled is None:
                self._compiled = CompiledSpace.build(self)
        return self._compiled

    def compile_eagerly(self, py_limit: int = 1 << 16
                        ) -> "CompiledSpace | None":
        """The tuning-entry compile policy (tuner construction, session
        start): compile up to the full limit when every constraint has a
        vectorized form, but cap Python-fallback sweeps at ``py_limit`` rows
        so a tiny tuning budget never pays seconds of predicate sweeping
        up front."""
        all_vec = all(c.vec is not None for c in self.constraints)
        return self.compiled(limit=None if all_vec else py_limit)

    # ------------------------------------------------------------------ #
    # basic accessors
    # ------------------------------------------------------------------ #
    @property
    def param_names(self) -> tuple[str, ...]:
        return tuple(p.name for p in self.params)

    def param(self, name: str) -> Param:
        return self._by_name[name]

    @property
    def cardinality(self) -> int:
        """Unconstrained cross-product size (Table VIII 'Cardinality')."""
        out = 1
        for p in self.params:
            out *= p.cardinality
        return out

    def satisfies(self, config: Config) -> bool:
        if self._compiled is not None:
            try:
                return bool(self._compiled.mask[self.flat_index(config)])
            except (ValueError, TypeError):
                pass                  # value outside the space: run predicates
        return all(c(config) for c in self.constraints)

    def violated(self, config: Config) -> list[str]:
        return [c.name for c in self.constraints if not c(config)]

    # ------------------------------------------------------------------ #
    # encode / decode
    # ------------------------------------------------------------------ #
    def encode(self, config: Config) -> tuple[int, ...]:
        """Config -> per-parameter value indices (surrogate features)."""
        return tuple(p.index_of(config[p.name]) for p in self.params)

    def decode(self, indices: Sequence[int]) -> Config:
        return {p.name: p.values[i] for p, i in zip(self.params, indices)}

    def flat_index(self, config: Config) -> int:
        """Config -> mixed-radix integer (stable unique id)."""
        idx = 0
        for p in self.params:
            idx = idx * p.cardinality + p.index_of(config[p.name])
        return idx

    def from_flat_index(self, idx: int) -> Config:
        out: Config = {}
        for p in reversed(self.params):
            idx, r = divmod(idx, p.cardinality)
            out[p.name] = p.values[r]
        return {p.name: out[p.name] for p in self.params}

    # ------------------------------------------------------------------ #
    # batched encode / flat-index
    # ------------------------------------------------------------------ #
    def encode_many(self, configs: Sequence[Config]) -> "np.ndarray":
        """(B, P) per-parameter index matrix for a batch of configs."""
        import numpy as np
        out = np.empty((len(configs), len(self.params)), dtype=np.int64)
        for i, p in enumerate(self.params):
            idx = p._index
            name = p.name
            if idx is None:
                out[:, i] = [p.values.index(c[name]) for c in configs]
            else:
                out[:, i] = [idx[c[name]] for c in configs]
        return out

    def flat_index_many(self, configs: Sequence[Config]) -> "np.ndarray":
        """Mixed-radix flat indices for a batch (matches ``flat_index``)."""
        import numpy as np
        if self.cardinality > 2 ** 62:     # int64 would overflow
            return np.array([self.flat_index(c) for c in configs],
                            dtype=object)
        from .spacetable import mixed_radix_strides
        strides = mixed_radix_strides([p.cardinality for p in self.params])
        return self.encode_many(configs) @ strides

    # ------------------------------------------------------------------ #
    # enumeration & sampling
    # ------------------------------------------------------------------ #
    def enumerate(self, constrained: bool = True) -> Iterator[Config]:
        for combo in itertools.product(*(p.values for p in self.params)):
            cfg = dict(zip(self.param_names, combo))
            if not constrained or self.satisfies(cfg):
                yield cfg

    def valid_configs(self) -> list[Config]:
        """All constraint-satisfying configs in ``enumerate`` order —
        vectorized through the compiled table when the space fits the
        compile limit, bit-identical to ``list(self.enumerate())``."""
        comp = self.compiled()
        if comp is not None:
            return comp.valid_configs()
        return list(self.enumerate(constrained=True))

    def constrained_cardinality(self, limit: int | None = None) -> int:
        """Exact count of constraint-satisfying configs (Table VIII
        'Constrained').  ``limit`` caps the count (a count that reaches
        ``limit`` stops there and returns ``limit``)."""
        comp = self.compiled()
        if comp is not None:
            return comp.n_valid if limit is None else min(comp.n_valid, limit)
        n = 0
        for _ in self.enumerate(constrained=True):
            n += 1
            if limit is not None and n >= limit:
                return n
        return n

    def sample(self, rng: random.Random, max_tries: int = 10_000) -> Config:
        """Uniform sample from the *constrained* space via rejection.

        With a compiled table the constraint evaluation per try collapses to
        one mask lookup; the rng draw sequence (one ``choice`` per parameter
        per try) is unchanged, so compiled and legacy paths return the same
        configs for the same rng state.
        """
        comp = self._compiled
        if comp is not None:
            mask, strides = comp.mask, comp.strides
            for _ in range(max_tries):
                row = 0
                vals = []
                for i, p in enumerate(self.params):
                    v = rng.choice(p.values)
                    vals.append(v)
                    row += p.index_of(v) * int(strides[i])
                if mask[row]:
                    return dict(zip(self.param_names, vals))
        else:
            for _ in range(max_tries):
                cfg = {p.name: rng.choice(p.values) for p in self.params}
                if self.satisfies(cfg):
                    return cfg
        raise RuntimeError(
            f"{self.name}: could not sample a valid config in {max_tries} tries")

    def sample_batch(self, n: int, seed: int = 0) -> list[Config]:
        rng = random.Random(seed)
        return [self.sample(rng) for _ in range(n)]

    def sample_distinct(self, n: int, seed: int = 0,
                        max_tries_factor: int = 200) -> list[Config]:
        """Up to ``n`` distinct valid configs (the paper's 10 000-random-configs
        protocol)."""
        rng = random.Random(seed)
        seen: set[int] = set()
        out: list[Config] = []
        tries = 0
        while len(out) < n and tries < n * max_tries_factor:
            tries += 1
            cfg = self.sample(rng)
            key = self.flat_index(cfg)
            if key not in seen:
                seen.add(key)
                out.append(cfg)
        return out

    # ------------------------------------------------------------------ #
    # neighborhoods (local search, FFG/centrality)
    # ------------------------------------------------------------------ #
    def neighbors(self, config: Config, constrained: bool = True,
                  adjacent_only: bool = False) -> Iterator[Config]:
        """Hamming-distance-1 neighbors: change one parameter to another value.

        ``adjacent_only`` restricts moves to the next/previous value in the
        parameter's ordered list (the FFG in Schoonhoven et al. uses full
        Hamming-1; local search may prefer adjacent moves for numeric params).
        """
        for p in self.params:
            cur = config[p.name]
            i = p.index_of(cur)
            if adjacent_only:
                candidates = [j for j in (i - 1, i + 1) if 0 <= j < p.cardinality]
            else:
                candidates = [j for j in range(p.cardinality) if j != i]
            for j in candidates:
                cfg = dict(config)
                cfg[p.name] = p.values[j]
                if not constrained or self.satisfies(cfg):
                    yield cfg

    def neighbors_list(self, config: Config, constrained: bool = True,
                       adjacent_only: bool = False) -> list[Config]:
        """``list(self.neighbors(...))``, served from the compiled CSR
        neighbor table when available (same configs, same order)."""
        if constrained and not adjacent_only and self._compiled is not None:
            comp = self._compiled
            try:
                row = self.flat_index(config)
            except (ValueError, TypeError):
                row = -1
            if row >= 0:
                rows = comp.neighbor_rows(row)
                if rows is not None:      # invalid current row: fall back
                    return comp.decode_many(rows)
        return list(self.neighbors(config, constrained, adjacent_only))

    def random_neighbor(self, config: Config, rng: random.Random,
                        max_tries: int = 1000) -> Config:
        for _ in range(max_tries):
            p = rng.choice(self.params)
            v = rng.choice(p.values)
            if v == config[p.name]:
                continue
            cfg = dict(config)
            cfg[p.name] = v
            if self.satisfies(cfg):
                return cfg
        return dict(config)

    # ------------------------------------------------------------------ #
    # reductions (Table VIII 'Reduced')
    # ------------------------------------------------------------------ #
    def reduce(self, keep: Sequence[str], frozen: Config | None = None,
               name: str | None = None) -> "SearchSpace":
        """Project onto ``keep`` params; others frozen to ``frozen`` (default:
        first value).  Constraints are re-wrapped over the frozen context."""
        frozen = dict(frozen or {})
        for p in self.params:
            if p.name not in keep:
                frozen.setdefault(p.name, p.values[0])
        kept = [p for p in self.params if p.name in keep]

        def wrap(c: Constraint) -> Constraint:
            def fn(cfg: Config, _c=c) -> bool:
                full = dict(frozen)
                full.update(cfg)
                return _c(full)

            vec = None
            if c.vec is not None:     # frozen params become constant columns
                def vec(cols: dict, _c=c):
                    import numpy as np
                    n = len(next(iter(cols.values())))
                    full = {k: np.full(n, v) for k, v in frozen.items()
                            if k not in cols}
                    full.update(cols)
                    return _c.vec(full)
            return Constraint(c.name, fn, vec)

        return SearchSpace(kept, [wrap(c) for c in self.constraints],
                           name=name or f"{self.name}-reduced")

    def __repr__(self) -> str:  # pragma: no cover
        return (f"SearchSpace({self.name!r}, {len(self.params)} params, "
                f"|S|={self.cardinality}, {len(self.constraints)} constraints)")


def powers_of_two(lo: int, hi: int) -> tuple[int, ...]:
    """Inclusive powers of two between lo and hi."""
    out = []
    v = 1
    while v <= hi:
        if v >= lo:
            out.append(v)
        v *= 2
    return tuple(out)


def divisors(n: int) -> tuple[int, ...]:
    out = [d for d in range(1, n + 1) if n % d == 0]
    return tuple(out)


def multiples(step: int, lo: int, hi: int) -> tuple[int, ...]:
    return tuple(range(lo - lo % step + (step if lo % step else 0), hi + 1, step)) \
        if lo % step else tuple(range(lo, hi + 1, step))
