"""Results database — BAT-style cachefiles.

Full-space / sampled evaluation data is expensive to (re)compute, and every
analysis (Figs 1-6, Table VIII) reads the same tables.  We persist one JSON
file per (problem × arch) under a cache directory, plus tuner-run traces.

orjson + zstd keep multi-100k-row tables compact when available (the
``[fast]`` extra); otherwise we fall back to stdlib ``json`` + ``zlib``.
The compressor is identified by the frame header — zstd frames start with
the magic ``28 B5 2F FD``, zlib streams with ``0x78`` — so files written by
either path load under the other without corrupting the cache (reading a
zstd file does require zstandard).
"""

from __future__ import annotations

import json
import math
import os
from dataclasses import dataclass, field
from pathlib import Path
from typing import Sequence

# _ZSTD_MAGIC and zstandard are re-exported for the tests' storage probes
from .compression import ZSTD_MAGIC as _ZSTD_MAGIC  # noqa: F401
from .compression import zstandard  # noqa: F401
from .compression import compress, decompress
from .problem import Trial, TunableProblem
from .space import Config, SearchSpace

try:  # optional fast path: pip install .[fast]
    import orjson
except ImportError:  # pragma: no cover - depends on environment
    orjson = None


def _np_default(obj):
    """stdlib-json fallback for numpy scalars/arrays (orjson handles these
    natively via OPT_SERIALIZE_NUMPY)."""
    if hasattr(obj, "item"):
        return obj.item()
    if hasattr(obj, "tolist"):
        return obj.tolist()
    raise TypeError(f"not JSON serializable: {type(obj)!r}")


def _json_dumps(obj) -> bytes:
    if orjson is not None:
        return orjson.dumps(obj, option=orjson.OPT_SERIALIZE_NUMPY)
    return json.dumps(obj, default=_np_default,
                      separators=(",", ":")).encode()


def _json_loads(raw: bytes):
    return orjson.loads(raw) if orjson is not None else json.loads(raw)


def _dump(obj) -> bytes:
    return compress(_json_dumps(obj), level=6)


def _load(raw: bytes):
    return _json_loads(decompress(raw, what="cachefile"))


@dataclass
class ResultTable:
    """Evaluated configs for one (problem, arch): the unit of analysis."""

    problem: str
    arch: str
    param_names: tuple[str, ...]
    configs: list[tuple]          # encoded index tuples (compact)
    objectives: list[float]       # seconds; inf => invalid on this arch
    protocol: str = "exhaustive"  # or "sampled:<n>:<seed>"
    meta: dict = field(default_factory=dict)

    # -- accessors -------------------------------------------------------- #
    def __len__(self) -> int:
        return len(self.objectives)

    def finite(self) -> list[float]:
        return [o for o in self.objectives if math.isfinite(o)]

    def best(self) -> tuple[tuple, float]:
        i = min(range(len(self.objectives)), key=lambda j: self.objectives[j])
        return self.configs[i], self.objectives[i]

    def decode(self, space: SearchSpace, encoded: tuple) -> Config:
        return space.decode(encoded)

    @staticmethod
    def from_trials(problem: TunableProblem, arch: str,
                    trials: Sequence[Trial], protocol: str) -> "ResultTable":
        sp = problem.space
        rows = [getattr(t, "row", None) for t in trials]
        if trials and all(r is not None for r in rows):
            # row-born trials (row-native sessions, journal-v2 replays):
            # the encoded tuples ARE the mixed-radix codes of the rows, so
            # build them in one vectorized pass — no config dict is ever
            # decoded just to be re-encoded here
            from .spacetable import CompiledSpace
            codes = CompiledSpace.codes_for(sp, rows)
            configs = [tuple(c) for c in codes.tolist()]
        else:
            configs = [sp.encode(t.config) for t in trials]
        return ResultTable(
            problem=problem.name, arch=arch, param_names=sp.param_names,
            configs=configs,
            objectives=[t.objective if t.valid else math.inf for t in trials],
            protocol=protocol)

    # -- (de)serialization ------------------------------------------------- #
    def to_bytes(self) -> bytes:
        return _dump({
            "problem": self.problem, "arch": self.arch,
            "param_names": list(self.param_names),
            "configs": [list(c) for c in self.configs],
            "objectives": [None if math.isinf(o) else o for o in self.objectives],
            "protocol": self.protocol, "meta": self.meta})

    @staticmethod
    def from_bytes(raw: bytes) -> "ResultTable":
        d = _load(raw)
        return ResultTable(
            problem=d["problem"], arch=d["arch"],
            param_names=tuple(d["param_names"]),
            configs=[tuple(c) for c in d["configs"]],
            objectives=[math.inf if o is None else float(o)
                        for o in d["objectives"]],
            protocol=d.get("protocol", "?"), meta=d.get("meta", {}))


class ResultsDB:
    """Directory-backed cache of :class:`ResultTable` and tuner traces."""

    def __init__(self, root: str | Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def _path(self, problem: str, arch: str, protocol: str) -> Path:
        safe = protocol.replace(":", "_")
        return self.root / f"{problem}.{arch}.{safe}.json.zst"

    def has(self, problem: str, arch: str, protocol: str) -> bool:
        return self._path(problem, arch, protocol).exists()

    def list_tables(self) -> list[tuple[str, str, str]]:
        """Every cached ``(problem, arch, protocol)`` key, sorted.

        The inverse of :meth:`_path`'s naming scheme: problem and arch
        never contain dots, so the first two dot-fields are exact and the
        remainder is the (``:``-mangled) protocol.  Unparsable strays in
        the cache directory are ignored — consumers (the servedb
        distiller) must not fall over a hand-dropped file.
        """
        out = []
        for p in self.root.glob("*.json.zst"):
            parts = p.name[:-len(".json.zst")].split(".")
            if len(parts) >= 3:
                out.append((parts[0], parts[1], ".".join(parts[2:])))
        return sorted(out)

    def put(self, table: ResultTable) -> Path:
        p = self._path(table.problem, table.arch, table.protocol)
        tmp = p.with_suffix(".tmp")
        tmp.write_bytes(table.to_bytes())
        os.replace(tmp, p)          # atomic commit
        return p

    def get(self, problem: str, arch: str, protocol: str) -> ResultTable:
        return ResultTable.from_bytes(
            self._path(problem, arch, protocol).read_bytes())

    def get_or_compute(self, problem: TunableProblem, arch: str,
                       protocol: str = "exhaustive", n: int = 10_000,
                       seed: int = 0) -> ResultTable:
        """The paper's data protocol: exhaustive where feasible, otherwise
        ``n`` distinct random configs."""
        key = protocol if protocol == "exhaustive" else f"sampled_{n}_{seed}"
        if self.has(problem.name, arch, key):
            return self.get(problem.name, arch, key)
        if protocol == "exhaustive":
            trials = problem.exhaustive(arch)
        else:
            trials = problem.sampled(n, seed, arch)
        table = ResultTable.from_trials(problem, arch, trials, key)
        self.put(table)
        return table
