"""Compiled search-space engine: the array-native view of a ``SearchSpace``.

The iterator API on :class:`~repro.core.space.SearchSpace` (``enumerate`` /
``neighbors`` / rejection ``sample``) is per-config Python work — fine for a
few hundred evaluations, prohibitive for the exhaustive analyses (fitness-
flow-graph centrality, Table VIII cardinality accounting) that need the whole
constrained landscape materialized per architecture.  A :class:`CompiledSpace`
pays that cost once, vectorized:

* **mixed-radix enumeration** — the full cross product as per-column index
  arithmetic on ``arange(cardinality)``; row ``r`` of the (virtual) code
  matrix *is* flat index ``r`` (``SearchSpace.flat_index`` order, last
  parameter fastest), so flat indices double as row ids,
* **vectorized constraints** — a :class:`~repro.core.space.Constraint` may
  carry a declarative ``vec(cols) -> bool[N]`` evaluated over column arrays;
  constraints without one fall back to the Python predicate, evaluated in
  declaration order only on rows still alive (preserving ``satisfies``'s
  short-circuit semantics exactly),
* a cached **valid-row mask** + valid-row index (exact constrained counts,
  O(1) membership),
* **rejection-free uniform sampling** from the valid set,
* batched ``encode_many`` / ``decode_many`` / ``flat_index_many``,
* **Hamming-1 neighbor tables in CSR form** over the valid set, in the same
  per-node order as ``SearchSpace.neighbors`` (parameter order, then value
  order) so consumers can swap paths bit-for-bit,
* **row-native draws** for the index-native tuners:
  :meth:`sample_row_rejection` and :meth:`random_neighbor_row` replicate the
  legacy ``SearchSpace.sample`` / ``random_neighbor`` rng draw sequences
  exactly (pure-int row arithmetic + one mask lookup per try, no dicts), and
  :meth:`sample_neighbor_alias` draws a Hamming-1 move in O(1) from per-row
  alias tables over the CSR neighbor lists (same conditional distribution as
  the rejection sampler, different — shorter — draw sequence),
* an **on-disk cache** (``.npz``) of the mask and neighbor tables, keyed by a
  structural fingerprint of the space.

Every compiled path is required to agree exactly with the legacy iterator
path — the property tests in ``tests/test_spacetable.py`` enforce it — so
consumers (tuners, the orchestrator, the analyses) switch transparently.

Vectorized constraints see *value* columns (``cols[name][r]`` is the value of
parameter ``name`` in row ``r``) and must be total functions of the full
cross product: they are evaluated on all rows at once, not only on rows that
passed earlier constraints.  Python predicates keep the short-circuit
ordering guarantee instead.
"""

from __future__ import annotations

import hashlib
import os
import random
from pathlib import Path
from typing import TYPE_CHECKING, Sequence

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from .space import Config, SearchSpace

#: spaces larger than this are not compiled implicitly (callers can still
#: pass an explicit higher limit to ``SearchSpace.compiled``).
DEFAULT_COMPILE_LIMIT = 1 << 21

_CACHE_VERSION = 1
_CACHE_DIR_ENV = "REPRO_SPACE_CACHE"
_UNSET = object()
_cache_dir: object = _UNSET


def set_cache_dir(path: str | Path | None) -> None:
    """Set the process-wide exhaustive-table cache directory.  ``None``
    disables caching outright (including the ``REPRO_SPACE_CACHE``
    environment default, which applies only while unset)."""
    global _cache_dir
    _cache_dir = Path(path) if path is not None else None


def get_cache_dir() -> Path | None:
    if _cache_dir is not _UNSET:
        return _cache_dir  # type: ignore[return-value]
    env = os.environ.get(_CACHE_DIR_ENV)
    return Path(env) if env else None


#: probe rows hashed into the fingerprint (see space_fingerprint)
_FINGERPRINT_PROBES = 128


def space_fingerprint(space: "SearchSpace") -> str:
    """Structural identity of a space: name, parameters (names + values, in
    order), constraint names, and the constraints' *behaviour* on a
    deterministic probe set.

    Constraint callables close over problem constants (shapes etc.) that
    ``repr`` cannot see, so two same-named spaces with different closures
    must not share a cache entry.  We therefore evaluate the raw constraint
    chain on ~128 rows spread across the cross product and hash the
    accept/reject bits — any semantic difference visible on the probes
    changes the fingerprint.  (Two constraint sets that agree on every probe
    would still collide; delete the cache entry when editing constraints
    in place.)"""
    h = hashlib.sha256()
    h.update(f"v{_CACHE_VERSION}|{space.name}".encode())
    for p in space.params:
        h.update(f"|{p.name}={p.values!r}".encode())
    for c in space.constraints:
        h.update(f"|c:{c.name}".encode())
    if space.constraints:
        n = space.cardinality
        rows = np.unique(np.linspace(0, n - 1, min(n, _FINGERPRINT_PROBES),
                                     dtype=np.int64))
        bits = []
        for r in rows:
            cfg = space.from_flat_index(int(r))
            # the raw declaration-order chain, not the compiled mask (the
            # fingerprint is computed while building that mask)
            bits.append("1" if all(c(cfg) for c in space.constraints)
                        else "0")
        h.update(("|probe:" + "".join(bits)).encode())
    return h.hexdigest()[:16]


def mixed_radix_strides(cards: Sequence[int]) -> np.ndarray:
    """Place values of the mixed-radix encoding used everywhere in the
    suite: ``strides[i] = prod(cards[i+1:])``, so
    ``flat_index == codes @ strides`` (``SearchSpace.flat_index`` order,
    last parameter fastest).  The single authority for this math — the
    row==flat-index invariant depends on every site using it."""
    cards = np.asarray(cards, dtype=np.int64)
    cp = np.cumprod(cards[::-1])
    return np.concatenate(([1], cp[:-1]))[::-1].astype(np.int64)


def rows_from_codes(cards: Sequence[int],
                    codes: np.ndarray | Sequence[Sequence[int]]) -> np.ndarray:
    """Inverse of :meth:`CompiledSpace.codes_for`: fold mixed-radix code
    rows back into flat indices (``codes @ strides``).  Kept next to
    :func:`mixed_radix_strides` so every encoder and decoder of the
    row==flat-index invariant shares the same two functions — the servedb
    binary export writes rows through this and a serving process can
    decode them with plain ``divmod``."""
    codes = np.asarray(codes, dtype=np.int64)
    if codes.size == 0:
        return np.zeros(0, dtype=np.int64)
    return codes @ mixed_radix_strides(cards)


def _value_array(values: tuple) -> np.ndarray:
    """Per-parameter value column as a numpy array (object dtype when the
    values are heterogeneous)."""
    try:
        arr = np.asarray(values)
        if arr.shape == (len(values),):
            return arr
    except (ValueError, TypeError):
        pass
    arr = np.empty(len(values), dtype=object)
    arr[:] = values
    return arr


class CompiledSpace:
    """Array-native materialization of one :class:`SearchSpace`.

    Build via :meth:`build` (or ``space.compiled()``, which caches the result
    on the space).  Rows are flat indices: ``row == space.flat_index(config)``
    for the config the row encodes.
    """

    def __init__(self, space: "SearchSpace", mask: np.ndarray,
                 nbr_indptr: np.ndarray | None = None,
                 nbr_indices: np.ndarray | None = None,
                 cache_path: Path | None = None):
        self.space = space
        #: where this table persists (set by :meth:`build` when caching)
        self.cache_path = cache_path
        self.cards = np.array([p.cardinality for p in space.params],
                              dtype=np.int64)
        self.strides = mixed_radix_strides(self.cards)
        self.n_total = int(self.strides[0] * self.cards[0])
        if mask.shape != (self.n_total,):
            raise ValueError("mask shape does not match the space")
        self.mask = mask
        self.valid_rows = np.flatnonzero(mask).astype(np.int64)
        #: row -> position in ``valid_rows`` (-1 for invalid rows)
        self.row_pos = np.full(self.n_total, -1, dtype=np.int64)
        self.row_pos[self.valid_rows] = np.arange(len(self.valid_rows))
        self._nbr_indptr = nbr_indptr
        self._nbr_indices = nbr_indices
        self._alias: tuple[np.ndarray, np.ndarray] | None = None
        self._value_arrays: list[np.ndarray] | None = None
        #: plain-int copies for the tuners' per-candidate hot loops (numpy
        #: scalar indexing costs ~3x a list lookup at these sizes)
        self.py_cards = [int(c) for c in self.cards]
        self.py_strides = [int(s) for s in self.strides]

    # ------------------------------------------------------------------ #
    # construction
    # ------------------------------------------------------------------ #
    @staticmethod
    def build(space: "SearchSpace",
              cache_dir: str | Path | None = None) -> "CompiledSpace":
        """Compile ``space``; loads from / saves to the table cache when a
        cache directory is configured."""
        from ..telemetry import metrics as _metrics
        cache_dir = Path(cache_dir) if cache_dir is not None \
            else get_cache_dir()
        path = None
        if cache_dir is not None:
            path = cache_dir / f"{space.name}-{space_fingerprint(space)}.npz"
            loaded = CompiledSpace._load(space, path)
            if loaded is not None:
                _metrics.counter("space_cache.hit", space=space.name).inc()
                return loaded
            _metrics.counter("space_cache.miss", space=space.name).inc()
        comp = CompiledSpace(space, CompiledSpace._compute_mask(space),
                             cache_path=path)
        if path is not None:
            comp.save(path)
        return comp

    @staticmethod
    def codes_for(space: "SearchSpace",
                  rows: np.ndarray | None = None) -> np.ndarray:
        """Mixed-radix code matrix for ``rows`` (default: all rows), one
        vectorized pass per column.  Row ``r``'s codes decode to
        ``space.from_flat_index(r)``."""
        cards = [p.cardinality for p in space.params]
        if rows is None:
            n = 1
            for c in cards:
                n *= c
            rows = np.arange(n, dtype=np.int64)
        else:
            rows = np.asarray(rows, dtype=np.int64)
        codes = np.empty((len(rows), len(cards)), dtype=np.int64)
        rem = rows
        for i in range(len(cards) - 1, -1, -1):
            rem, codes[:, i] = np.divmod(rem, cards[i])
        return codes

    @staticmethod
    def _compute_mask(space: "SearchSpace") -> np.ndarray:
        cards = [p.cardinality for p in space.params]
        n = 1
        for c in cards:
            n *= c
        strides = mixed_radix_strides(cards)
        mask = np.ones(n, dtype=bool)
        names = space.param_names
        pyvals = [p.values for p in space.params]
        cols: dict[str, np.ndarray] | None = None
        codes: np.ndarray | None = None       # built only for py fallbacks
        for c in space.constraints:
            vec = getattr(c, "vec", None)
            if vec is not None:
                if cols is None:
                    # mixed-radix value columns by repeat/tile — identical
                    # to fancy-indexing the code matrix, without building it
                    cols = {nm: np.tile(np.repeat(_value_array(pv), s),
                                        n // (s * k))
                            for nm, pv, s, k
                            in zip(names, pyvals,
                                   (int(s) for s in strides), cards)}
                res = np.asarray(vec(cols), dtype=bool)
                if res.shape != (n,):
                    raise ValueError(
                        f"constraint {c.name!r}: vec returned shape "
                        f"{res.shape}, expected ({n},)")
                mask &= res
            else:
                # Python fallback, only on rows still alive — preserves the
                # declaration-order short-circuit of ``satisfies``.
                if codes is None:
                    codes = CompiledSpace.codes_for(space)
                alive = np.flatnonzero(mask)
                fn = c.fn
                drop = [r for r in alive
                        if not fn({nm: pv[j] for nm, pv, j
                                   in zip(names, pyvals, codes[r])})]
                if drop:
                    mask[drop] = False
        return mask

    # ------------------------------------------------------------------ #
    # basic accessors
    # ------------------------------------------------------------------ #
    @property
    def n_valid(self) -> int:
        return len(self.valid_rows)

    def decode_row(self, row: int) -> "Config":
        return self.space.from_flat_index(int(row))

    def decode_many(self, rows: Sequence[int] | np.ndarray) -> list["Config"]:
        """Batched decode: one numpy pass per column, then a zip into dicts.

        Type-homogeneous parameters (all-int / all-float / all-str values)
        take a fancy-index + ``tolist`` fast path; heterogeneous ones fall
        back to per-element lookups so decoded values are always ``==`` (and
        same-typed) to the originals in ``Param.values``.
        """
        rows = np.asarray(rows, dtype=np.int64)
        if not len(rows):
            return []
        codes = CompiledSpace.codes_for(self.space, rows)
        names = self.space.param_names
        columns = []
        for i, p in enumerate(self.space.params):
            t = type(p.values[0])
            if t in (int, float, str) \
                    and all(type(v) is t for v in p.values):
                columns.append(np.asarray(p.values)[codes[:, i]].tolist())
            else:
                pv = p.values
                columns.append([pv[j] for j in codes[:, i].tolist()])
        return [dict(zip(names, vals)) for vals in zip(*columns)]

    def encode_many(self, configs: Sequence["Config"]) -> np.ndarray:
        return self.space.encode_many(configs)

    def flat_index_many(self, configs: Sequence["Config"]) -> np.ndarray:
        return self.space.flat_index_many(configs)

    def valid_configs(self) -> list["Config"]:
        """All constraint-satisfying configs, in ``SearchSpace.enumerate``
        order (row order)."""
        return self.decode_many(self.valid_rows)

    def value_columns(self, rows: Sequence[int] | np.ndarray
                      ) -> dict[str, np.ndarray]:
        """Per-parameter *value* column arrays for ``rows`` — the same
        column form the vectorized constraints consume, fed to the
        per-kernel ``feature_columns`` overrides.  No dicts per config."""
        rows = np.asarray(rows, dtype=np.int64)
        codes = CompiledSpace.codes_for(self.space, rows)
        if self._value_arrays is None:
            self._value_arrays = [_value_array(p.values)
                                  for p in self.space.params]
        return {p.name: va[codes[:, i]]
                for i, (p, va) in enumerate(zip(self.space.params,
                                                self._value_arrays))}

    # ------------------------------------------------------------------ #
    # sampling
    # ------------------------------------------------------------------ #
    def sample_row(self, rng: random.Random) -> int:
        """O(1) rejection-free uniform draw from the valid set."""
        if not len(self.valid_rows):
            raise RuntimeError(f"{self.space.name}: no valid configs")
        return int(self.valid_rows[rng.randrange(len(self.valid_rows))])

    def sample(self, rng: random.Random) -> "Config":
        return self.decode_row(self.sample_row(rng))

    def sample_rows_distinct(self, n: int, rng: random.Random) -> np.ndarray:
        """Up to ``n`` distinct valid rows, uniformly without replacement."""
        k = min(n, len(self.valid_rows))
        return self.valid_rows[np.asarray(
            rng.sample(range(len(self.valid_rows)), k), dtype=np.int64)]

    def sample_row_rejection(self, rng: random.Random,
                             max_tries: int = 10_000) -> int:
        """Rejection draw of a valid row with the *legacy draw sequence*.

        ``SearchSpace.sample`` draws one ``rng.choice(p.values)`` per
        parameter per try; ``rng.choice(seq)`` consumes exactly one
        ``_randbelow(len(seq))``, which is what ``rng.randrange(card)``
        consumes too — so this method returns the row of the config the
        legacy path would return, from the identical rng state, without
        building a single dict.  The index-native tuners use it wherever
        their scalar oracles call ``space.sample``.
        """
        mask = self.mask
        cards = self.py_cards
        strides = self.py_strides
        # rng.choice(seq) == seq[rng._randbelow(len(seq))] in CPython;
        # calling _randbelow directly skips randrange's argument ceremony
        # while consuming the identical draws (trajectory tests enforce it)
        randbelow = rng._randbelow
        n_params = len(cards)
        for _ in range(max_tries):
            row = 0
            for i in range(n_params):
                row += randbelow(cards[i]) * strides[i]
            if mask[row]:
                return row
        raise RuntimeError(
            f"{self.space.name}: could not sample a valid config "
            f"in {max_tries} tries")

    def random_neighbor_row(self, row: int, rng: random.Random,
                            max_tries: int = 1000) -> int:
        """Row-native ``SearchSpace.random_neighbor``: identical draw
        sequence (param choice, value choice, retry on self/invalid),
        returning ``row`` itself when no move is found — all in int
        arithmetic plus one mask lookup per try."""
        mask = self.mask
        cards = self.py_cards
        strides = self.py_strides
        n_params = len(cards)
        randbelow = rng._randbelow      # draw-identical to rng.choice
        for _ in range(max_tries):
            d = randbelow(n_params)
            j = randbelow(cards[d])
            cur = (row // strides[d]) % cards[d]
            if j == cur:
                continue
            nrow = row + (j - cur) * strides[d]
            if mask[nrow]:
                return nrow
        return row

    # ------------------------------------------------------------------ #
    # alias-sampled neighbor moves
    # ------------------------------------------------------------------ #
    def edge_params(self) -> np.ndarray:
        """Per-CSR-edge moved-parameter index: edge ``e`` changes parameter
        ``edge_params()[e]`` of its source config."""
        indptr, indices = self.csr_neighbors()
        src_pos = np.repeat(np.arange(len(indptr) - 1, dtype=np.int64),
                            np.diff(indptr))
        delta = np.abs(self.valid_rows[indices] - self.valid_rows[src_pos])
        # a Hamming-1 move along dim d shifts the row by |j-cur| * strides[d]
        # with |j-cur| < cards[d], so strides[d] <= |delta| < strides[d-1]:
        # the dim is the first stride <= |delta| in the descending stride
        # vector, i.e. the count of strides strictly greater than |delta|.
        out = np.searchsorted(-self.strides, -delta, side="left")
        return out.astype(np.int64)

    def neighbor_alias(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-row Vose alias tables over the CSR neighbor lists, weighted
        ``1/cards[moved_param]`` — the conditional distribution of the
        accepted legacy rejection draw (uniform parameter, then uniform
        value).  Aligned with ``csr_neighbors()``: entries
        ``indptr[k]:indptr[k+1]`` are row ``k``'s (prob, alias) table, alias
        indices *local* to the segment.  Built lazily, kept in memory."""
        if self._alias is None:
            indptr, indices = self.csr_neighbors()
            w = 1.0 / self.cards[self.edge_params()].astype(np.float64)
            prob = np.ones(len(indices), dtype=np.float64)
            alias = np.zeros(len(indices), dtype=np.int64)
            for k in range(len(indptr) - 1):
                lo, hi = int(indptr[k]), int(indptr[k + 1])
                deg = hi - lo
                if deg == 0:
                    continue
                p = w[lo:hi] * (deg / w[lo:hi].sum())
                small = [i for i in range(deg) if p[i] < 1.0]
                large = [i for i in range(deg) if p[i] >= 1.0]
                while small and large:
                    s, g = small.pop(), large.pop()
                    prob[lo + s] = p[s]
                    alias[lo + s] = g
                    p[g] = (p[g] + p[s]) - 1.0
                    (small if p[g] < 1.0 else large).append(g)
                for i in large + small:       # numerical leftovers: prob 1
                    prob[lo + i] = 1.0
                    alias[lo + i] = i
        else:
            return self._alias
        self._alias = (prob, alias)
        return self._alias

    def sample_neighbor_alias(self, row: int, rng: random.Random) -> int:
        """O(1) draw of a valid Hamming-1 neighbor row of a *valid* ``row``
        from the alias tables (two rng draws: slot, coin).  Returns ``-1``
        when the row has no valid neighbors (degenerate CSR row) and raises
        ``ValueError`` for rows outside the valid set."""
        pos = int(self.row_pos[row])
        if pos < 0:
            raise ValueError(f"row {row} is not a valid config row")
        indptr, indices = self.csr_neighbors()
        lo, hi = int(indptr[pos]), int(indptr[pos + 1])
        deg = hi - lo
        if deg == 0:
            return -1
        prob, alias = self.neighbor_alias()
        k = rng.randrange(deg)
        if rng.random() >= prob[lo + k]:
            k = int(alias[lo + k])
        return int(self.valid_rows[indices[lo + k]])

    # ------------------------------------------------------------------ #
    # CSR Hamming-1 neighbor tables
    # ------------------------------------------------------------------ #
    def csr_neighbors(self) -> tuple[np.ndarray, np.ndarray]:
        """(indptr, indices) over valid-set *positions*: the Hamming-1
        neighbors of ``valid_rows[k]`` are
        ``valid_rows[indices[indptr[k]:indptr[k+1]]]``, listed in
        ``SearchSpace.neighbors`` order (parameter order, then value order).
        Built lazily, cached, and re-persisted to this table's own cache
        file (the one :meth:`build` loaded from / saved to) when caching is
        enabled."""
        if self._nbr_indptr is None:
            self._nbr_indptr, self._nbr_indices = self._build_csr()
            if self.cache_path is not None:
                self.save(self.cache_path)
        return self._nbr_indptr, self._nbr_indices

    def _build_csr(self) -> tuple[np.ndarray, np.ndarray]:
        vrows = self.valid_rows
        nv = len(vrows)
        if nv == 0:
            return (np.zeros(1, dtype=np.int64),
                    np.empty(0, dtype=np.int64))
        vcodes = CompiledSpace.codes_for(self.space, vrows)
        src_parts: list[np.ndarray] = []
        dst_parts: list[np.ndarray] = []
        for i in range(len(self.cards)):
            stride = int(self.strides[i])
            base = vrows - vcodes[:, i] * stride
            for j in range(int(self.cards[i])):
                sel = np.flatnonzero(vcodes[:, i] != j)
                if not len(sel):
                    continue
                pos = self.row_pos[base[sel] + j * stride]
                hit = pos >= 0
                src_parts.append(sel[hit])
                dst_parts.append(pos[hit])
        src = np.concatenate(src_parts) if src_parts \
            else np.empty(0, dtype=np.int64)
        dst = np.concatenate(dst_parts) if dst_parts \
            else np.empty(0, dtype=np.int64)
        # stable sort by source keeps the (param, value) generation order
        # within each node — the legacy ``neighbors`` iteration order.
        order = np.argsort(src, kind="stable")
        indptr = np.zeros(nv + 1, dtype=np.int64)
        np.cumsum(np.bincount(src, minlength=nv), out=indptr[1:])
        return indptr, dst[order]

    def neighbor_rows(self, row: int) -> np.ndarray | None:
        """Valid Hamming-1 neighbor rows of a *valid* row (``None`` when
        ``row`` itself is invalid — callers fall back to the iterator)."""
        pos = int(self.row_pos[row])
        if pos < 0:
            return None
        indptr, indices = self.csr_neighbors()
        return self.valid_rows[indices[indptr[pos]:indptr[pos + 1]]]

    # ------------------------------------------------------------------ #
    # persistence
    # ------------------------------------------------------------------ #
    def save(self, path: str | Path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        payload = {
            "fingerprint": np.frombuffer(
                space_fingerprint(self.space).encode(), dtype=np.uint8),
            "n_total": np.array([self.n_total], dtype=np.int64),
            "mask_bits": np.packbits(self.mask),
        }
        if self._nbr_indptr is not None:
            payload["nbr_indptr"] = self._nbr_indptr
            payload["nbr_indices"] = self._nbr_indices
        tmp = path.with_suffix(".tmp.npz")
        with open(tmp, "wb") as f:
            np.savez_compressed(f, **payload)
        os.replace(tmp, path)
        return path

    @staticmethod
    def _load(space: "SearchSpace", path: Path) -> "CompiledSpace | None":
        if not path.exists():
            return None
        try:
            with np.load(path) as z:
                fp = bytes(z["fingerprint"]).decode()
                if fp != space_fingerprint(space) \
                        or int(z["n_total"][0]) != space.cardinality:
                    return None
                mask = np.unpackbits(
                    z["mask_bits"], count=space.cardinality).astype(bool)
                indptr = z["nbr_indptr"] if "nbr_indptr" in z else None
                indices = z["nbr_indices"] if "nbr_indices" in z else None
        except (OSError, ValueError, KeyError):  # corrupt cache: rebuild
            return None
        return CompiledSpace(space, mask, indptr, indices, cache_path=path)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"CompiledSpace({self.space.name!r}, rows={self.n_total}, "
                f"valid={self.n_valid}, "
                f"csr={'built' if self._nbr_indptr is not None else 'lazy'})")
