"""Fig 2: convergence towards the optimum under random search.

Protocol from the paper: draw random configs (without replacement) from the
recorded table, track best-so-far, repeat 100 times, report the median curve
of *relative performance* (t_best_table / t_best_so_far) vs evaluations.
"""

from __future__ import annotations

import numpy as np

from ..results import ResultTable


def random_search_curves(table: ResultTable, budget: int = 1000,
                         repeats: int = 100, seed: int = 0) -> np.ndarray:
    """(repeats, budget) best-so-far *relative performance* curves."""
    t = np.array(table.objectives)
    finite = np.isfinite(t)
    idx_pool = np.nonzero(finite)[0]
    t_best = t[idx_pool].min()
    rng = np.random.default_rng(seed)
    budget = min(budget, len(idx_pool))
    curves = np.empty((repeats, budget))
    for r in range(repeats):
        picks = rng.choice(idx_pool, size=budget, replace=False)
        best = np.minimum.accumulate(t[picks])
        curves[r] = t_best / best
    return curves


def median_curve(table: ResultTable, budget: int = 1000, repeats: int = 100,
                 seed: int = 0) -> np.ndarray:
    return np.median(random_search_curves(table, budget, repeats, seed), axis=0)


def evals_to_reach(curve: np.ndarray, level: float = 0.9) -> int:
    """First evaluation index (1-based) at which the curve reaches ``level``
    relative performance; -1 if never.  This is the paper's '90% after N
    evaluations' statistic (C2)."""
    hit = np.nonzero(curve >= level)[0]
    return int(hit[0]) + 1 if len(hit) else -1
