"""Fig 1 + Fig 4: performance-distribution shape and speedup over median.

The paper plots, per benchmark × architecture, the distribution of *relative
performance* centered on the median configuration, and reports the max
speedup of the best configuration over the median one.
"""

from __future__ import annotations

import math

import numpy as np

from ..results import ResultTable


def relative_performance(table: ResultTable) -> np.ndarray:
    """Per-config performance relative to the best (1.0 == optimal).

    Performance = 1/time, so rel-perf = t_best / t.  Invalid configs are
    dropped (they are the 'did not compile' analogue).
    """
    t = np.array(table.finite())
    if len(t) == 0:
        return np.array([])
    return t.min() / t


def distribution_profile(table: ResultTable,
                         quantiles: np.ndarray | None = None) -> dict:
    """Quantile profile of rel-perf, normalized to the median config —
    the data behind Fig 1's density curves."""
    rel = relative_performance(table)
    if quantiles is None:
        quantiles = np.linspace(0.0, 1.0, 101)
    q = np.quantile(rel, quantiles)
    med = float(np.median(rel))
    return {
        "quantiles": quantiles.tolist(),
        "rel_perf": q.tolist(),
        "rel_to_median": (q / med).tolist(),
        "median": med,
        "n": int(len(rel)),
    }


def speedup_over_median(table: ResultTable) -> float:
    """Fig 4: t_median / t_best."""
    t = np.array(table.finite())
    if len(t) == 0:
        return math.nan
    return float(np.median(t) / t.min())


def top_cluster_fraction(table: ResultTable, within: float = 0.10) -> float:
    """Fraction of configs within ``within`` of optimal performance —
    quantifies the 'Hotspot high-performing cluster' observation (C1)."""
    rel = relative_performance(table)
    if len(rel) == 0:
        return math.nan
    return float((rel >= 1.0 - within).mean())
