"""Fig 5: performance portability of optimal configurations across
architectures (paper: four GPUs; here: four TPU generations).

transfer[i][j] = perf(opt_i on arch_j) / perf(opt_j on arch_j) — the relative
performance on arch_j when simply reusing arch_i's optimum.
"""

from __future__ import annotations

import math

import numpy as np

from ..results import ResultTable


def portability_matrix(tables: dict[str, ResultTable]) -> dict:
    """``tables``: arch -> exhaustive/sampled table over the SAME config set.

    Requires the config universe to overlap (exhaustive tables, or sampled
    tables generated with the same seed — the suite guarantees the latter).
    """
    archs = list(tables)
    # objective lookup per arch: encoded config -> seconds
    look: dict[str, dict[tuple, float]] = {}
    best_cfg: dict[str, tuple] = {}
    best_t: dict[str, float] = {}
    for a, tb in tables.items():
        d = {tuple(c): o for c, o in zip(tb.configs, tb.objectives)}
        look[a] = d
        fin = {c: o for c, o in d.items() if math.isfinite(o)}
        bc = min(fin, key=fin.get)
        best_cfg[a], best_t[a] = bc, fin[bc]

    n = len(archs)
    mat = np.full((n, n), np.nan)
    for i, ai in enumerate(archs):           # row: where the optimum came from
        for j, aj in enumerate(archs):       # col: where it is deployed
            t = look[aj].get(best_cfg[ai], math.inf)
            mat[i, j] = best_t[aj] / t if math.isfinite(t) else 0.0
    return {"archs": archs, "matrix": mat.tolist(),
            "best_config": {a: list(best_cfg[a]) for a in archs},
            "worst_transfer": float(np.nanmin(mat)),
            "best_off_diagonal": float(
                np.nanmax(mat[~np.eye(n, dtype=bool)])) if n > 1 else math.nan}
