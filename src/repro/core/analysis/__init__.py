"""Landscape analyses — one module per paper figure/table."""

from .centrality import (FFG, build_ffg, build_ffg_reference,
                         centrality_curve, pagerank,
                         proportion_of_centrality)
from .convergence import evals_to_reach, median_curve, random_search_curves
from .distribution import (distribution_profile, relative_performance,
                           speedup_over_median, top_cluster_fraction)
from .importance import (feature_importance, fit_surrogate, important_params,
                         reduced_space)
from .portability import portability_matrix
from .spacestats import reduced_stats, space_stats

__all__ = [
    "build_ffg", "build_ffg_reference", "pagerank",
    "proportion_of_centrality", "centrality_curve",
    "FFG", "median_curve", "random_search_curves", "evals_to_reach",
    "distribution_profile", "relative_performance", "speedup_over_median",
    "top_cluster_fraction", "feature_importance", "fit_surrogate",
    "important_params", "reduced_space", "portability_matrix",
    "space_stats", "reduced_stats",
]
