"""Table VIII: search-space cardinality accounting.

Columns mirror the paper: Cardinality (raw cross product), Constrained
(structural constraints), Valid (runs on a given architecture — here: finite
cost-model time, i.e. fits that generation's VMEM), Reduced (PFI ≥ 0.05
params only), Reduce-Constrained.
"""

from __future__ import annotations


from ..problem import TunableProblem
from ..space import SearchSpace


def space_stats(problem: TunableProblem, archs: tuple[str, ...] = ("v5e",),
                exhaustive_limit: int = 300_000,
                sample_n: int = 4000) -> dict:
    """Cardinality accounting, exact wherever the compiled table reaches.

    'Constrained' comes straight from the compiled valid-row mask whenever
    the cross product fits the compile limit (so spaces the sampling
    estimator previously approximated are now exact); the per-arch 'Valid'
    column needs a cost-model evaluation per constrained config and stays
    exhaustive only under ``exhaustive_limit``.
    """
    sp = problem.space
    card = sp.cardinality
    out = {"problem": problem.name, "cardinality": card}

    comp = sp.compiled()
    if comp is not None:
        out["constrained"] = comp.n_valid
    elif card <= exhaustive_limit:
        out["constrained"] = sp.constrained_cardinality()
    else:
        # estimate the constrained fraction by sampling the raw cross product
        import random
        rng = random.Random(0)
        hits = 0
        for _ in range(sample_n):
            cfg = {p.name: rng.choice(p.values) for p in sp.params}
            if sp.satisfies(cfg):
                hits += 1
        out["constrained"] = int(card * hits / sample_n)

    exact_constrained = comp is not None or card <= exhaustive_limit
    valid = {}
    if exact_constrained and out["constrained"] <= exhaustive_limit:
        for a in archs:
            valid[a] = sum(1 for t in problem.exhaustive(a) if t.ok)
        out["exact"] = True
    else:
        for a in archs:
            trials = problem.sampled(min(sample_n, 2000), 0, a)
            frac = sum(t.ok for t in trials) / max(1, len(trials))
            valid[a] = int(out["constrained"] * frac)
        out["exact"] = False
    out["valid"] = valid
    return out


def reduced_stats(space: SearchSpace, reduced: SearchSpace,
                  exhaustive_limit: int = 300_000) -> dict:
    out = {"reduced": reduced.cardinality}
    if reduced.compiled() is not None \
            or reduced.cardinality <= exhaustive_limit:
        out["reduce_constrained"] = reduced.constrained_cardinality()
    else:
        import random
        rng = random.Random(0)
        hits = sum(
            1 for _ in range(2000)
            if reduced.satisfies({p.name: rng.choice(p.values)
                                  for p in reduced.params}))
        out["reduce_constrained"] = int(reduced.cardinality * hits / 2000)
    return out
