"""Table VIII: search-space cardinality accounting.

Columns mirror the paper: Cardinality (raw cross product), Constrained
(structural constraints), Valid (runs on a given architecture — here: finite
cost-model time, i.e. fits that generation's VMEM), Reduced (PFI ≥ 0.05
params only), Reduce-Constrained.
"""

from __future__ import annotations

import math

from ..problem import TunableProblem
from ..space import SearchSpace


def space_stats(problem: TunableProblem, archs: tuple[str, ...] = ("v5e",),
                exhaustive_limit: int = 300_000,
                sample_n: int = 4000) -> dict:
    sp = problem.space
    card = sp.cardinality
    out = {"problem": problem.name, "cardinality": card}

    if card <= exhaustive_limit:
        constrained = sp.constrained_cardinality()
        out["constrained"] = constrained
        valid = {}
        for a in archs:
            nv = sum(1 for t in problem.exhaustive(a) if t.ok)
            valid[a] = nv
        out["valid"] = valid
        out["exact"] = True
    else:
        # estimate the constrained fraction by sampling the raw cross product
        import random
        rng = random.Random(0)
        hits = 0
        for _ in range(sample_n):
            cfg = {p.name: rng.choice(p.values) for p in sp.params}
            if sp.satisfies(cfg):
                hits += 1
        out["constrained"] = int(card * hits / sample_n)
        valid = {}
        for a in archs:
            trials = problem.sampled(min(sample_n, 2000), 0, a)
            frac = sum(t.ok for t in trials) / max(1, len(trials))
            valid[a] = int(out["constrained"] * frac)
        out["valid"] = valid
        out["exact"] = False
    return out


def reduced_stats(space: SearchSpace, reduced: SearchSpace,
                  exhaustive_limit: int = 300_000) -> dict:
    out = {"reduced": reduced.cardinality}
    if reduced.cardinality <= exhaustive_limit:
        out["reduce_constrained"] = reduced.constrained_cardinality()
    else:
        import random
        rng = random.Random(0)
        hits = sum(
            1 for _ in range(2000)
            if reduced.satisfies({p.name: rng.choice(p.values)
                                  for p in reduced.params}))
        out["reduce_constrained"] = int(reduced.cardinality * hits / 2000)
    return out
