"""Fig 3: proportion-of-centrality search-difficulty metric.

From Schoonhoven et al.: build the fitness flow graph (FFG) — every valid
config is a node, with a directed edge to each Hamming-1 neighbor of strictly
lower fitness.  A random walk on the FFG mimics randomized first-improvement
local search; PageRank gives the expected arrival mass.  The metric is the
share of PageRank mass held by the "suitably good" local minima
(fitness ≤ (1+p)·f_opt) relative to all local minima — higher == easier for
local search.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..results import ResultTable
from ..space import SearchSpace


@dataclass
class FFG:
    n: int
    src: np.ndarray            # edge sources (node ids)
    dst: np.ndarray            # edge destinations
    fitness: np.ndarray        # per-node objective (seconds)
    minima: np.ndarray         # bool: node is a local minimum (no out-edges)


def build_ffg(space: SearchSpace, table: ResultTable) -> FFG:
    """FFG over the *valid* configs recorded in ``table``.

    Neighborhood = Hamming-1 within the recorded set (for sampled tables this
    is the induced subgraph, same protocol the paper uses when exhaustive
    enumeration is out of reach).

    Vectorized: encoded configs become mixed-radix flat keys; each
    (parameter, value) Hamming-1 move is one arithmetic shift of the key
    column joined back against the sorted key set via ``searchsorted``.
    Produces node ids, fitness, and edge arrays identical to
    :func:`build_ffg_reference` (the per-config dict-loop original, kept as
    the test oracle) — this join is what makes exhaustive FFGs affordable
    for the benchmarks the paper skipped for cost.
    """
    obj = np.asarray(table.objectives, dtype=np.float64)
    enc = np.asarray(table.configs, dtype=np.int64)
    if enc.ndim != 2:                 # empty table: keep a (0, P) shape
        enc = enc.reshape(-1, len(space.params))
    finite = np.isfinite(obj)
    obj, enc = obj[finite], enc[finite]

    from ..spacetable import mixed_radix_strides
    cards = np.array([p.cardinality for p in space.params], dtype=np.int64)
    strides = mixed_radix_strides(cards)
    flat = enc @ strides if len(obj) else np.empty(0, dtype=np.int64)

    # dedup keeping the first occurrence; node ids in first-occurrence order
    uniq, first = np.unique(flat, return_index=True)
    order = np.argsort(first, kind="stable")      # node id -> sorted position
    inv_order = np.empty(len(uniq), dtype=np.int64)
    inv_order[order] = np.arange(len(uniq))       # sorted position -> node id
    occ = first[order]
    fitness = obj[occ]
    node_flat = flat[occ]
    node_codes = enc[occ]
    n = len(uniq)

    # Exhaustive tables over a compiled space reuse its precomputed CSR
    # neighbor table (arch-independent, cached on the space and on disk):
    # the join collapses to one fitness filter over the edge list.
    comp = space.compiled(build=False)
    if comp is not None and n == comp.n_valid \
            and np.array_equal(uniq, comp.valid_rows):
        indptr, indices = comp.csr_neighbors()
        src_pos = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
        fit_by_pos = fitness[inv_order]
        keep = fit_by_pos[indices] < fit_by_pos[src_pos]
        src = inv_order[src_pos[keep]]
        dst = inv_order[indices[keep]]
        e_order = np.argsort(src, kind="stable")
        src, dst = src[e_order], dst[e_order]
        outdeg = np.bincount(src, minlength=n)
        return FFG(n=n, src=src, dst=dst, fitness=fitness,
                   minima=outdeg == 0)

    src_parts: list[np.ndarray] = []
    dst_parts: list[np.ndarray] = []
    ids = np.arange(n, dtype=np.int64)
    for d in range(len(cards)):
        stride = int(strides[d])
        card = int(cards[d])
        cur = node_codes[:, d]
        base = node_flat - cur * stride
        # all (node, value) Hamming-1 moves along dim d in one (n, card) pass
        q = (base[:, None] + np.arange(card, dtype=np.int64) * stride).ravel()
        pos = np.searchsorted(uniq, q)
        pos_c = np.minimum(pos, max(n - 1, 0))
        hit = (uniq[pos_c] == q) if n else np.zeros(len(q), dtype=bool)
        not_self = (np.arange(card)[None, :] != cur[:, None]).ravel()
        ok = hit & not_self
        u_ids = np.repeat(ids, card)[ok]
        v_ids = inv_order[pos_c[ok]]
        better = fitness[v_ids] < fitness[u_ids]
        src_parts.append(u_ids[better])
        dst_parts.append(v_ids[better])
    src = np.concatenate(src_parts) if src_parts else np.empty(0, np.int64)
    dst = np.concatenate(dst_parts) if dst_parts else np.empty(0, np.int64)
    # stable sort by source reproduces the reference edge emission order:
    # within each part edges come (node-major, value order), parts come in
    # parameter order, so equal-src runs sort to (parameter, value) order
    e_order = np.argsort(src, kind="stable")
    src, dst = src[e_order], dst[e_order]
    outdeg = np.bincount(src, minlength=n)
    return FFG(n=n, src=src, dst=dst, fitness=fitness, minima=outdeg == 0)


def build_ffg_reference(space: SearchSpace, table: ResultTable) -> FFG:
    """Per-config dict-loop FFG construction — the scalar reference that
    :func:`build_ffg` must match bit-for-bit (see tests/test_spacetable.py)."""
    enc2id: dict[tuple, int] = {}
    fit: list[float] = []
    for cfg_enc, obj in zip(table.configs, table.objectives):
        if np.isfinite(obj) and tuple(cfg_enc) not in enc2id:
            enc2id[tuple(cfg_enc)] = len(fit)
            fit.append(obj)
    fitness = np.array(fit)
    n = len(fitness)
    cards = [p.cardinality for p in space.params]
    src_l: list[int] = []
    dst_l: list[int] = []
    for enc, u in enc2id.items():
        fu = fitness[u]
        for d, c in enumerate(cards):
            for v_idx in range(c):
                if v_idx == enc[d]:
                    continue
                nb = enc[:d] + (v_idx,) + enc[d + 1:]
                v = enc2id.get(nb)
                if v is not None and fitness[v] < fu:
                    src_l.append(u)
                    dst_l.append(v)
    src = np.array(src_l, dtype=np.int64)
    dst = np.array(dst_l, dtype=np.int64)
    outdeg = np.bincount(src, minlength=n)
    return FFG(n=n, src=src, dst=dst, fitness=fitness, minima=outdeg == 0)


def pagerank(ffg: FFG, damping: float = 0.85, iters: int = 100,
             tol: float = 1e-10) -> np.ndarray:
    """Power iteration; dangling (local-minimum) mass redistributes uniformly."""
    n = ffg.n
    if n == 0:
        return np.array([])
    outdeg = np.bincount(ffg.src, minlength=n).astype(np.float64)
    dangling_nodes = outdeg == 0
    r = np.full(n, 1.0 / n)
    for _ in range(iters):
        # bincount-scatter: np.add.at is an order of magnitude slower on the
        # ~100-iteration power loop run per (benchmark, arch)
        w = r[ffg.src] / outdeg[ffg.src]
        contrib = np.bincount(ffg.dst, weights=w, minlength=n)
        dangling = r[dangling_nodes].sum()
        r_new = (1 - damping) / n + damping * (contrib + dangling / n)
        if np.abs(r_new - r).sum() < tol:
            r = r_new
            break
        r = r_new
    return r / r.sum()


def proportion_of_centrality(space: SearchSpace, table: ResultTable,
                             p: float = 0.10, damping: float = 0.85) -> float:
    """Share of minima PageRank mass on minima with fitness ≤ (1+p)·f_opt."""
    ffg = build_ffg(space, table)
    if ffg.n == 0 or not ffg.minima.any():
        return float("nan")
    pr = pagerank(ffg, damping)
    f_opt = ffg.fitness.min()
    good = ffg.minima & (ffg.fitness <= (1.0 + p) * f_opt)
    total = pr[ffg.minima].sum()
    return float(pr[good].sum() / total) if total > 0 else float("nan")


def centrality_curve(space: SearchSpace, table: ResultTable,
                     ps: np.ndarray | None = None) -> dict:
    """Metric as a function of p (the paper sweeps the proportion p)."""
    ffg = build_ffg(space, table)
    pr = pagerank(ffg)
    f_opt = ffg.fitness.min()
    total = pr[ffg.minima].sum()
    if ps is None:
        ps = np.linspace(0.0, 0.5, 26)
    vals = []
    for p in ps:
        good = ffg.minima & (ffg.fitness <= (1.0 + p) * f_opt)
        vals.append(float(pr[good].sum() / total) if total > 0 else float("nan"))
    return {"p": np.asarray(ps).tolist(), "proportion": vals,
            "n_nodes": ffg.n, "n_minima": int(ffg.minima.sum()),
            "n_edges": int(len(ffg.src))}
