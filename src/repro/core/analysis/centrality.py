"""Fig 3: proportion-of-centrality search-difficulty metric.

From Schoonhoven et al.: build the fitness flow graph (FFG) — every valid
config is a node, with a directed edge to each Hamming-1 neighbor of strictly
lower fitness.  A random walk on the FFG mimics randomized first-improvement
local search; PageRank gives the expected arrival mass.  The metric is the
share of PageRank mass held by the "suitably good" local minima
(fitness ≤ (1+p)·f_opt) relative to all local minima — higher == easier for
local search.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..results import ResultTable
from ..space import SearchSpace


@dataclass
class FFG:
    n: int
    src: np.ndarray            # edge sources (node ids)
    dst: np.ndarray            # edge destinations
    fitness: np.ndarray        # per-node objective (seconds)
    minima: np.ndarray         # bool: node is a local minimum (no out-edges)


def build_ffg(space: SearchSpace, table: ResultTable) -> FFG:
    """FFG over the *valid* configs recorded in ``table``.

    Neighborhood = Hamming-1 within the recorded set (for sampled tables this
    is the induced subgraph, same protocol the paper uses when exhaustive
    enumeration is out of reach).
    """
    enc2id: dict[tuple, int] = {}
    fit: list[float] = []
    for cfg_enc, obj in zip(table.configs, table.objectives):
        if np.isfinite(obj) and tuple(cfg_enc) not in enc2id:
            enc2id[tuple(cfg_enc)] = len(fit)
            fit.append(obj)
    fitness = np.array(fit)
    n = len(fitness)
    cards = [p.cardinality for p in space.params]
    src_l: list[int] = []
    dst_l: list[int] = []
    for enc, u in enc2id.items():
        fu = fitness[u]
        for d, c in enumerate(cards):
            for v_idx in range(c):
                if v_idx == enc[d]:
                    continue
                nb = enc[:d] + (v_idx,) + enc[d + 1:]
                v = enc2id.get(nb)
                if v is not None and fitness[v] < fu:
                    src_l.append(u)
                    dst_l.append(v)
    src = np.array(src_l, dtype=np.int64)
    dst = np.array(dst_l, dtype=np.int64)
    outdeg = np.bincount(src, minlength=n)
    return FFG(n=n, src=src, dst=dst, fitness=fitness, minima=outdeg == 0)


def pagerank(ffg: FFG, damping: float = 0.85, iters: int = 100,
             tol: float = 1e-10) -> np.ndarray:
    """Power iteration; dangling (local-minimum) mass redistributes uniformly."""
    n = ffg.n
    if n == 0:
        return np.array([])
    outdeg = np.bincount(ffg.src, minlength=n).astype(np.float64)
    r = np.full(n, 1.0 / n)
    for _ in range(iters):
        contrib = np.zeros(n)
        w = np.where(outdeg[ffg.src] > 0, r[ffg.src] / outdeg[ffg.src], 0.0)
        np.add.at(contrib, ffg.dst, w)
        dangling = r[outdeg == 0].sum()
        r_new = (1 - damping) / n + damping * (contrib + dangling / n)
        if np.abs(r_new - r).sum() < tol:
            r = r_new
            break
        r = r_new
    return r / r.sum()


def proportion_of_centrality(space: SearchSpace, table: ResultTable,
                             p: float = 0.10, damping: float = 0.85) -> float:
    """Share of minima PageRank mass on minima with fitness ≤ (1+p)·f_opt."""
    ffg = build_ffg(space, table)
    if ffg.n == 0 or not ffg.minima.any():
        return float("nan")
    pr = pagerank(ffg, damping)
    f_opt = ffg.fitness.min()
    good = ffg.minima & (ffg.fitness <= (1.0 + p) * f_opt)
    total = pr[ffg.minima].sum()
    return float(pr[good].sum() / total) if total > 0 else float("nan")


def centrality_curve(space: SearchSpace, table: ResultTable,
                     ps: np.ndarray | None = None) -> dict:
    """Metric as a function of p (the paper sweeps the proportion p)."""
    ffg = build_ffg(space, table)
    pr = pagerank(ffg)
    f_opt = ffg.fitness.min()
    total = pr[ffg.minima].sum()
    if ps is None:
        ps = np.linspace(0.0, 0.5, 26)
    vals = []
    for p in ps:
        good = ffg.minima & (ffg.fitness <= (1.0 + p) * f_opt)
        vals.append(float(pr[good].sum() / total) if total > 0 else float("nan"))
    return {"p": np.asarray(ps).tolist(), "proportion": vals,
            "n_nodes": ffg.n, "n_minima": int(ffg.minima.sum()),
            "n_edges": int(len(ffg.src))}
