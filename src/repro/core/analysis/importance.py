"""Fig 6 + Table VIII reduction: surrogate fit + Permutation Feature Importance.

Protocol from the paper: train a boosted-tree regressor on (config -> perf),
report R², compute PFI per parameter, note that PFI sums ≫ 1 imply parameter
interactions (need for global optimization), and reduce the space to params
with PFI ≥ 0.05 on any architecture.
"""

from __future__ import annotations

import numpy as np

from ..mlmodel import GradientBoostedTrees, permutation_importance, r2_score
from ..results import ResultTable
from ..space import SearchSpace


def fit_surrogate(table: ResultTable, n_trees: int = 150, max_depth: int = 6,
                  seed: int = 0, max_rows: int | None = 20_000
                  ) -> tuple[GradientBoostedTrees, np.ndarray, np.ndarray]:
    """Fit GBDT on log-time over the finite rows; returns (model, X, y)."""
    rows = [(c, o) for c, o in zip(table.configs, table.objectives)
            if np.isfinite(o)]
    if max_rows is not None and len(rows) > max_rows:
        rng = np.random.default_rng(seed)
        take = rng.choice(len(rows), size=max_rows, replace=False)
        rows = [rows[i] for i in take]
    X = np.array([c for c, _ in rows], dtype=np.int64)
    y = np.log(np.array([o for _, o in rows]))
    model = GradientBoostedTrees(n_trees=n_trees, max_depth=max_depth,
                                 min_samples_leaf=3, seed=seed).fit(X, y)
    return model, X, y


def feature_importance(table: ResultTable, seed: int = 0,
                       n_repeats: int = 3) -> dict:
    """Returns per-parameter PFI, R², and the interaction indicator (sum)."""
    model, X, y = fit_surrogate(table, seed=seed)
    r2 = r2_score(y, model.predict(X))
    pfi = permutation_importance(model, X, y, n_repeats=n_repeats, seed=seed)
    return {
        "params": list(table.param_names),
        "pfi": pfi.tolist(),
        "r2": float(r2),
        "pfi_sum": float(pfi.sum()),     # ≫ 1 -> interactions (C6)
    }


def important_params(importances: dict[str, dict],
                     threshold: float = 0.05) -> list[str]:
    """Params with PFI ≥ threshold on ANY architecture (paper's reduction rule)."""
    keep: set[str] = set()
    names: list[str] = []
    for imp in importances.values():
        names = imp["params"]
        for name, v in zip(imp["params"], imp["pfi"]):
            if v >= threshold:
                keep.add(name)
    return [n for n in names if n in keep]


def reduced_space(space: SearchSpace, importances: dict[str, dict],
                  best_config: dict, threshold: float = 0.05) -> SearchSpace:
    """Table VIII 'Reduced': keep only important params, freeze the rest to
    the best-known configuration's values."""
    keep = important_params(importances, threshold)
    frozen = {k: v for k, v in best_config.items() if k not in keep}
    return space.reduce(keep, frozen=frozen, name=f"{space.name}-reduced")
