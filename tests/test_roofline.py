"""Roofline extraction: collective-bytes HLO parsing, per-device
cost_analysis semantics, and the loop-corrected probe algebra."""

import jax
import jax.numpy as jnp
import pytest

from repro.roofline import HW, CellReport, collective_bytes
from repro.roofline.probe import Terms


# ------------------------------------------------------------------ #
# HLO collective parser
# ------------------------------------------------------------------ #
HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ag = f32[512,256]{1,0} all-gather(%p0), dimensions={0}
  %ar = bf16[1024]{0} all-reduce(%x), to_apply=%sum
  %rs = f32[64,256]{1,0} reduce-scatter(%ag), dimensions={0}
  %a2a = f32[128,256]{1,0} all-to-all(%p0), dimensions={0}
  %cps = (f32[32,32]{1,0}, f32[32,32]{1,0}) collective-permute-start(%y)
  %cpd = f32[32,32]{1,0} collective-permute-done(%cps)
  %ags = f32[256,16]{1,0} all-gather-start(%z), dimensions={0}
  %agd = f32[256,16]{1,0} all-gather-done(%ags)
  ROOT %t = f32[] constant(0)
}
"""


def test_collective_bytes_parser():
    out = collective_bytes(HLO_SAMPLE)
    assert out["all-gather"] == 512 * 256 * 4 + 256 * 16 * 4   # start counted once
    assert out["all-reduce"] == 1024 * 2                       # bf16
    assert out["reduce-scatter"] == 64 * 256 * 4
    assert out["all-to-all"] == 128 * 256 * 4
    assert out["collective-permute"] == 32 * 32 * 4            # tuple halved
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_collective_bytes_real_lowering():
    """An explicitly sharded psum must show up as all-reduce bytes."""
    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    @jax.jit
    def f(a):
        return jax.lax.with_sharding_constraint(
            a.sum(axis=0, keepdims=True), NamedSharding(mesh, P()))

    # single device: no collectives expected — parser returns 0, not junk
    txt = f.lower(jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile().as_text()
    assert collective_bytes(txt)["total"] >= 0.0


# ------------------------------------------------------------------ #
# cost_analysis semantics the probe relies on
# ------------------------------------------------------------------ #
def _flops(fn, *args):
    ca = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0))


def test_cost_analysis_counts_scan_body_once():
    """The documented XLA behaviour that motivates the probe corrections."""
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def body(c, w):
        return jnp.tanh(c @ w), None

    def stepL(L):
        ws = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
        return _flops(lambda x, ws: jax.lax.scan(body, x, ws)[0], x, ws)

    f4, f16 = stepL(4), stepL(16)
    assert f4 == pytest.approx(f16, rel=0.01)        # body counted once
    one = 2 * 128 ** 3
    assert f4 == pytest.approx(one, rel=0.05)


def test_probe_correction_matches_unrolled():
    """step + (G-1)*group  ==  fully unrolled flops (the probe algebra)."""
    G = 8
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((G, 128, 128), jnp.float32)
    w1 = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def body(c, w):
        return jnp.tanh(c @ w), None

    f_step = _flops(lambda x, ws: jax.lax.scan(body, x, ws)[0], x, ws)
    f_grp = _flops(lambda x, w: body(x, w)[0], x, w1)
    f_unrl = _flops(
        lambda x, ws: jax.lax.scan(body, x, ws, unroll=G)[0], x, ws)
    corrected = f_step + (G - 1) * f_grp
    assert corrected == pytest.approx(f_unrl, rel=0.02)


def test_terms_algebra():
    a = Terms(1.0, 2.0, 3.0, {"all-reduce": 3.0})
    b = Terms(10.0, 20.0, 30.0, {"all-gather": 30.0})
    s = a + 2 * b
    assert s.flops == 21.0 and s.hbm == 42.0 and s.coll == 63.0
    assert s.coll_by_op == {"all-reduce": 3.0, "all-gather": 60.0}


def test_cell_report_bound_and_mfu():
    r = CellReport(
        arch="a", shape="s", mesh="m", chips=2,
        flops_per_chip=HW["peak_flops_bf16"] * 1e-3,     # 1 ms compute
        hbm_bytes_per_chip=HW["hbm_bw"] * 2e-3,          # 2 ms memory
        coll_bytes_per_chip=HW["ici_bw"] * 0.5e-3,       # 0.5 ms collective
        coll_by_op={}, peak_memory_per_chip=0.0,
        model_flops=HW["peak_flops_bf16"] * 1e-3 * 2 * 0.5,
        t_compute=1e-3, t_memory=2e-3, t_collective=0.5e-3)
    assert r.bound == "memory"
    assert r.t_total_overlap == pytest.approx(2e-3)
    assert r.mfu == pytest.approx(0.25)
    assert r.useful_flops_ratio == pytest.approx(0.5)
