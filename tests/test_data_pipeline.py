"""Data pipeline fault-tolerance contract: deterministic addressing, host
sharding, resumability, learnable structure."""

import numpy as np

from repro.data import DataConfig, make_pipeline


def _cfg(**kw):
    base = dict(vocab=256, seq_len=64, global_batch=8, seed=13)
    base.update(kw)
    return DataConfig(**base)


def test_deterministic_and_resumable():
    p1 = make_pipeline(_cfg())
    p2 = make_pipeline(_cfg())                  # fresh process, same seed
    for step in (0, 5, 1000):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        np.testing.assert_array_equal(b1["labels"], b2["labels"])
    # different steps produce different data
    assert not np.array_equal(p1.batch_at(0)["tokens"],
                              p1.batch_at(1)["tokens"])


def test_host_sharding_consistency():
    """Concatenating two hosts' slices == the single-host global batch —
    the elastic-restart invariant (restarting on a different host grid
    replays the same global batch)."""
    full = make_pipeline(_cfg()).batch_at(7)
    h0 = make_pipeline(_cfg(), host_id=0, n_hosts=2).batch_at(7)
    h1 = make_pipeline(_cfg(), host_id=1, n_hosts=2).batch_at(7)
    np.testing.assert_array_equal(
        full["tokens"], np.concatenate([h0["tokens"], h1["tokens"]]))
    q0 = make_pipeline(_cfg(), host_id=0, n_hosts=4).batch_at(7)
    np.testing.assert_array_equal(full["tokens"][:2], q0["tokens"])


def test_labels_are_shifted_tokens():
    b = make_pipeline(_cfg()).batch_at(3)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_tokens_in_vocab_and_shapes():
    cfg = _cfg(vocab=100, seq_len=32, global_batch=4)
    b = make_pipeline(cfg).batch_at(0)
    assert b["tokens"].shape == (4, 32)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 100


def test_markov_structure_is_learnable():
    """The source must be low-entropy relative to uniform — otherwise the
    train examples can't show learning."""
    cfg = _cfg(vocab=512, branching=16)
    p = make_pipeline(cfg)
    floor = p.entropy_floor()
    assert floor < 0.75 * np.log(cfg.vocab)     # well below uniform entropy
    assert floor > 0.0


def test_bad_host_split_rejected():
    import pytest
    with pytest.raises(ValueError):
        make_pipeline(_cfg(global_batch=5), host_id=0, n_hosts=2)
