"""End-to-end behaviour of the paper's system: the full BAT loop —
problem -> tuners -> results DB -> the five analyses — on real suite
kernels (cost-model objective, small protocols) plus the C1..C7 claim
*mechanisms* at test scale."""

import math

import numpy as np
import pytest

from repro.core.analysis.centrality import proportion_of_centrality
from repro.core.analysis.convergence import evals_to_reach, median_curve
from repro.core.analysis.distribution import (relative_performance,
                                              speedup_over_median)
from repro.core.analysis.importance import feature_importance
from repro.core.analysis.portability import portability_matrix
from repro.core.costmodel import ARCH_NAMES
from repro.core.results import ResultsDB, ResultTable
from repro.core.tuners import TUNERS, run_tuner
from repro.kernels.matmul.space import GemmProblem
from repro.kernels.nbody.space import NbodyProblem


@pytest.fixture(scope="module")
def gemm_tables(tmp_path_factory):
    """Sampled GEMM tables on all four TPU generations (module-cached)."""
    db = ResultsDB(tmp_path_factory.mktemp("db"))
    prob = GemmProblem()
    return prob, {a: db.get_or_compute(prob, a, protocol="sampled", n=600)
                  for a in ARCH_NAMES}


def test_every_tuner_tunes_a_real_kernel(gemm_tables):
    """The interoperability claim: all eight tuners drive the same problem
    through the same interface, unmodified."""
    prob, _ = gemm_tables
    results = {}
    for name, cls in TUNERS.items():
        res = run_tuner(cls(prob.space, seed=3), prob, budget=30)
        assert res.best.ok, name
        results[name] = res.best.objective
    best = min(results.values())
    assert best < math.inf
    # every tuner lands within 20x of the best-found (sanity, not a race)
    assert all(v < 20 * best for v in results.values()), results


def test_results_db_roundtrip_and_cache(gemm_tables, tmp_path):
    prob, tables = gemm_tables
    t = tables["v5e"]
    db2 = ResultsDB(tmp_path)
    p = db2.put(t)
    assert p.exists()
    back = db2.get(t.problem, t.arch, t.protocol)
    assert back.objectives == t.objectives
    assert back.param_names == t.param_names


def test_landscape_characteristics_on_real_kernel(gemm_tables):
    """C1/C4-style stats on the GEMM landscape: wide spread, real speedup
    over the median config, structure stable across generations."""
    _, tables = gemm_tables
    speeds = {a: speedup_over_median(t) for a, t in tables.items()}
    for a, s in speeds.items():
        assert s > 1.2, (a, s)       # tuning matters on every arch
    rel = relative_performance(tables["v5e"])
    assert rel.min() < 0.5           # bad configs are much worse than best


def test_convergence_statistic_on_real_kernel(gemm_tables):
    _, tables = gemm_tables
    med = median_curve(tables["v5e"], budget=300, repeats=25, seed=0)
    n90 = evals_to_reach(med, 0.9)
    assert n90 != -1
    assert np.all(np.diff(med) >= -1e-12)


def test_portability_across_tpu_generations(gemm_tables):
    """C5 mechanism: transferring optima across generations costs
    performance; diagonal is 1.0; same-family transfers are cheap.  A 0.0
    entry is legitimate — the source optimum does not *run* on the target
    (VMEM overflow == the paper's 'does not compile' case)."""
    _, tables = gemm_tables
    m = portability_matrix(tables)
    mat = np.array(m["matrix"])
    archs = m["archs"]
    assert np.allclose(np.diag(mat), 1.0)
    assert mat.min() < 0.999         # at least one lossy transfer
    i5e, i5p = archs.index("v5e"), archs.index("v5p")
    assert mat[i5e][i5p] > 0.8 and mat[i5p][i5e] > 0.8   # same family


def test_pfi_on_real_kernel(gemm_tables):
    """C6 mechanism: surrogate fits the landscape; a few parameters
    dominate; block shape must matter for GEMM."""
    _, tables = gemm_tables
    imp = feature_importance(tables["v5e"], seed=0)
    assert imp["r2"] > 0.8
    by_name = dict(zip(imp["params"], imp["pfi"]))
    blockish = max(by_name["block_m"], by_name["block_n"], by_name["block_k"])
    assert blockish >= 0.05


def test_centrality_on_small_kernel_space():
    """Fig 3 machinery on a real (small) kernel space end to end."""
    prob = NbodyProblem()
    trials = prob.sampled(400, seed=1, arch="v5e")
    table = ResultTable.from_trials(prob, "v5e", trials, "sampled_400_1")
    poc = proportion_of_centrality(prob.space, table, p=0.10)
    assert 0.0 <= poc <= 1.0 and not math.isnan(poc)


def test_invalid_configs_never_win(gemm_tables):
    prob, tables = gemm_tables
    t = tables["v5e"]
    _, best = t.best()
    assert math.isfinite(best)


def test_vmem_gate_varies_by_generation():
    """v4 has 32 MiB VMEM vs 128 MiB on v5e+: some configs must be valid on
    v5e but invalid on v4 (the 'compile failure' portability mechanism)."""
    prob = GemmProblem()
    n_flip = 0
    for cfg in prob.space.sample_distinct(300, seed=5):
        a = prob.evaluate(cfg, "v5e").ok
        b = prob.evaluate(cfg, "v4").ok
        n_flip += (a != b)
    assert n_flip > 0
