"""Doc-drift guards: quoted commands must run, links must resolve.

Docs rot when commands are renamed out from under them.  This suite
extracts every command quoted in ``docs/reproducing.md`` and the
orchestrator CLI module docstring and checks each against the real entry
points:

* ``python -m repro.orchestrator <sub> ...`` — the subcommand's
  ``--help`` is executed in-process and every quoted ``--flag`` must be
  accepted by its argparse parser;
* ``python -m benchmarks.X ...`` / ``python examples/X.py`` — the module
  file must exist and every quoted ``--flag`` must appear in its source
  (these modules run full benchmarks on import/main, so they are
  validated statically);
* a smoke subset of the orchestrator commands is *executed* end-to-end
  against a toy problem at tiny budgets.

Plus a markdown link check over ``README.md`` and ``docs/**/*.md``.
"""

from __future__ import annotations

import re
from pathlib import Path

import pytest

import repro.orchestrator.cli as cli_mod
from repro.orchestrator.cli import main as cli_main

ROOT = Path(__file__).resolve().parents[1]
DOC_SOURCES = {
    "docs/reproducing.md": (ROOT / "docs" / "reproducing.md").read_text(),
    "repro/orchestrator/cli.py docstring": cli_mod.__doc__,
}


# --------------------------------------------------------------------- #
# command extraction
# --------------------------------------------------------------------- #
def _commands(text: str) -> list[str]:
    """Every ``python ...`` command quoted in ``text``: fenced blocks,
    RST literal blocks, and inline backticks; continuation lines joined,
    env-var prefixes and comments stripped."""
    # join "\"-continued lines first
    text = re.sub(r"\\\s*\n\s*", " ", text)
    raw = []
    for line in text.splitlines():
        # inline backtick spans (table cells, prose)
        raw.extend(m.group(1) for m in
                   re.finditer(r"`((?:PYTHONPATH=\S+ +)?python[^`]*)`", line))
        raw.append(line)
    cmds = []
    for line in raw:
        line = line.strip().strip("`")
        line = re.sub(r"^\$\s+", "", line)
        line = re.sub(r"^PYTHONPATH=\S+\s+", "", line)
        if line.startswith("python ") or line.startswith("python3 "):
            cmds.append(line.split("#", 1)[0].strip().rstrip("&").strip())
    return cmds


ALL_COMMANDS = sorted({c for text in DOC_SOURCES.values()
                       for c in _commands(text)})


def _flags(cmd: str) -> list[str]:
    return re.findall(r"(--[a-z][a-z0-9-]*)", cmd)


def test_docs_actually_quote_commands():
    """The extraction itself must not silently rot: both sources carry
    orchestrator commands, and reproducing.md covers every paper-claim
    module."""
    assert any("repro.orchestrator" in c for c in ALL_COMMANDS)
    joined = " ".join(ALL_COMMANDS)
    for module in ("benchmarks.run", "benchmarks.table_portability"):
        assert module in joined, f"{module} not documented"
    for sub in ("submit", "status", "resume", "campaign", "worker",
                "fleet", "metrics", "doctor", "servedb", "surrogate",
                "lint"):
        assert any(f"repro.orchestrator {sub}" in c for c in ALL_COMMANDS), \
            f"orchestrator subcommand {sub!r} not documented"


@pytest.mark.parametrize("cmd", ALL_COMMANDS)
def test_quoted_command_matches_entry_point(cmd, capsys):
    parts = cmd.split()
    if parts[1] == "-m" and parts[2].startswith("repro.orchestrator"):
        if len(parts) == 3:                    # bare entry point mention
            with pytest.raises(SystemExit) as e:
                cli_main(["--help"])
            assert e.value.code == 0
            return
        sub = parts[3]
        assert sub in ("submit", "status", "resume", "campaign", "worker",
                       "fleet", "metrics", "doctor", "servedb", "surrogate",
                       "lint"), \
            f"unknown subcommand in {cmd!r}"
        # argparse exits 0 on --help and would exit 2 on unknown flags —
        # but --help doesn't validate, so check each flag against the
        # subparser's registered options instead
        with pytest.raises(SystemExit) as e:
            cli_main([sub, "--help"])
        assert e.value.code == 0
        help_text = capsys.readouterr().out
        for flag in _flags(cmd):
            assert flag in help_text, \
                f"{flag} quoted in docs but not accepted by {sub!r}"
    elif parts[1] == "-m":
        mod_path = ROOT / (parts[2].replace(".", "/") + ".py")
        assert mod_path.exists(), f"{cmd!r}: no module {parts[2]}"
        src = mod_path.read_text()
        for flag in _flags(cmd):
            assert flag in src, \
                f"{flag} quoted in docs but absent from {mod_path.name}"
    else:                                      # python examples/foo.py
        script = ROOT / parts[1]
        assert script.exists(), f"{cmd!r}: no script {parts[1]}"


def test_docs_smoke_orchestrator_commands(tmp_path, capsys):
    """Execute the documented submit/status/resume/campaign shapes
    end-to-end at smoke budgets (toy problem, tiny store)."""
    store = str(tmp_path / "sessions")
    assert cli_main(["submit", "--problem", "toy_quad", "--tuner", "genetic",
                     "--arch", "v5e", "--budget", "20", "--seed", "0",
                     "--workers", "2", "--store", store,
                     "--stop-after", "8"]) == 0
    sid = capsys.readouterr().out.split()[1]
    assert cli_main(["status", "--store", store]) == 0
    capsys.readouterr()
    assert cli_main(["resume", sid, "--store", store]) == 0
    capsys.readouterr()
    assert cli_main(["campaign", "--problems", "toy_quad",
                     "--tuners", "random", "--archs", "v5e,v4",
                     "--seeds", "0", "--budget", "10", "--workers", "2",
                     "--store", store]) == 0
    capsys.readouterr()
    # the broker shape: worker --max-jobs serves the campaign's jobs from
    # a thread, as the docs' detached-process form would
    import threading

    from repro.orchestrator import BrokerWorker, SQLiteBroker
    db = str(tmp_path / "queue.db")
    broker = SQLiteBroker(db)
    worker = BrokerWorker(broker, workers=2, lease_s=5.0, poll_s=0.005)
    stop = threading.Event()
    t = threading.Thread(target=worker.run, kwargs={"stop": stop},
                         daemon=True)
    t.start()
    try:
        assert cli_main(["campaign", "--problems", "toy_quad",
                         "--tuners", "random", "--archs", "v5e",
                         "--seeds", "1", "--budget", "10",
                         "--store", store, "--broker", db]) == 0
    finally:
        stop.set()
        t.join(timeout=30)
    out = capsys.readouterr().out
    assert "done" in out


# --------------------------------------------------------------------- #
# markdown link check
# --------------------------------------------------------------------- #
def _md_files() -> list[Path]:
    return [ROOT / "README.md", *sorted((ROOT / "docs").glob("**/*.md"))]


@pytest.mark.parametrize("md", _md_files(), ids=lambda p: p.name)
def test_markdown_links_resolve(md):
    text = md.read_text()
    # strip fenced code blocks — table syntax inside them is not a link
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    broken = []
    for label, target in re.findall(r"\[([^\]]+)\]\(([^)\s]+)\)", text):
        if re.match(r"^[a-z]+://", target) or target.startswith("mailto:"):
            continue                    # external: not checked offline
        path = target.split("#", 1)[0]
        if not path:
            continue                    # pure intra-page anchor
        if not (md.parent / path).exists():
            broken.append((label, target))
    assert not broken, f"broken relative links in {md.name}: {broken}"
