import sys
from pathlib import Path

# make `import sweeps` work from any pytest rootdir
sys.path.insert(0, str(Path(__file__).resolve().parent))
