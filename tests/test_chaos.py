"""Chaos conformance suite: injected faults must leave published
results bit-identical to the fault-free run.

The fault plane (:mod:`repro.orchestrator.chaos`) is exercised at every
seam it attacks — worker crash before complete, torn journal append,
heartbeat stall past the lease, evaluation hang, SQLite lock storm,
lease-clock skew — and each scenario asserts the survivor invariant:
traces, journals and trial info equal to a run with no faults at all.
Fleet-level properties run against BOTH broker backends through the
same parametrized fixture as ``test_broker.py``; supervisor policy
(backoff, crash-loop quarantine, queue-depth autoscaling, drain) is
unit-tested with fake processes and a fake clock, plus one real
SIGTERM-drain subprocess at the bottom.  ``repro doctor`` closes the
loop: the integrity checks that would have caught each fault offline.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import threading
import time
from pathlib import Path

import pytest

from repro.core.problem import FunctionProblem
from repro.core.space import Param, SearchSpace
from repro.orchestrator import (BrokerWorker, FaultPlan, FleetSupervisor,
                                MemoryBroker, SessionSpec, SessionStore,
                                SQLiteBroker, registry, run_campaign,
                                run_session)
from repro.orchestrator import chaos
from repro.orchestrator.chaos import ChaosCrash, FaultRule
from repro.orchestrator.cli import main as cli_main
from repro.orchestrator.doctor import diagnose
from repro.telemetry import metrics as tmetrics

ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture(autouse=True)
def _disarm_chaos():
    """Chaos is process-global (like the telemetry enable flag): never
    let a plan leak out of one test into the rest of the suite."""
    yield
    chaos.uninstall()


@pytest.fixture(params=["memory", "sqlite"])
def broker(request, tmp_path):
    b = (MemoryBroker() if request.param == "memory"
         else SQLiteBroker(tmp_path / "queue.db"))
    yield b
    b.close()


def _fleet(broker, n=2, lease_s=5.0, workers=2, **kw):
    """n BrokerWorker loops as daemon threads; returns (stop, threads).
    An injected :class:`ChaosCrash` kills the loop (that is the fault)
    but is swallowed at the thread boundary so pytest's unhandled-thread
    -exception hook stays quiet."""
    stop = threading.Event()
    members = [BrokerWorker(broker, workers=workers, lease_s=lease_s,
                            poll_s=0.005, **kw) for _ in range(n)]

    def _serve(w):
        try:
            w.run(stop=stop)
        except ChaosCrash:
            pass                       # this worker is "dead"

    threads = [threading.Thread(target=_serve, args=(w,), daemon=True)
               for w in members]
    for t in threads:
        t.start()
    return stop, threads


def _traces_equal(a, b) -> bool:
    return ([t.objective for t in a.trials] == [t.objective for t in b.trials]
            and [t.config for t in a.trials] == [t.config for t in b.trials]
            and [t.valid for t in a.trials] == [t.valid for t in b.trials])


def _slow_problem(per_eval_s=0.25):
    space = SearchSpace([Param("a", tuple(range(64)))], name="toy_slow")

    def fn(cfg, arch):
        time.sleep(per_eval_s)
        return float(cfg["a"] + 1)

    return FunctionProblem(space, fn, name="toy_slow")


def _plan(*rules, seed=7) -> FaultPlan:
    return FaultPlan(seed=seed, rules=rules)


# --------------------------------------------------------------------- #
# the plan itself: validation, round-trip, determinism
# --------------------------------------------------------------------- #
def test_plan_roundtrip_and_validation(tmp_path):
    plan = _plan(FaultRule("eval.hang", p=0.25, max_fires=3,
                           params={"hang_s": 1.5}),
                 FaultRule("worker.crash.before_complete", p=0.1,
                           after=5, params={"exit": True}))
    # file and inline forms load identically
    p = tmp_path / "plan.json"
    p.write_text(json.dumps(plan.to_json()))
    assert FaultPlan.load(p) == plan
    assert FaultPlan.load(json.dumps(plan.to_json())) == plan

    with pytest.raises(ValueError, match="unknown chaos site"):
        FaultRule("no.such.site")
    with pytest.raises(ValueError, match="not in"):
        FaultRule("eval.hang", p=1.5)
    with pytest.raises(ValueError, match="duplicate"):
        _plan(FaultRule("eval.hang"), FaultRule("eval.hang"))


def test_schedule_is_deterministic_and_salted():
    """Whether the n-th hit fires is a pure function of (seed, salt,
    site, n) — same plan, same salt => same fault sequence; a different
    salt (another worker) draws a different but replayable stream."""
    plan = _plan(FaultRule("eval.hang", p=0.4, after=3, max_fires=50,
                           params={"hang_s": 0.0}))

    def sequence(salt):
        chaos.install(plan, salt=salt)
        return [chaos.fire("eval.hang") is not None for _ in range(200)]

    a, b = sequence("s0g1"), sequence("s0g1")
    assert a == b
    assert not any(a[:3])                    # after=3 honored
    assert 20 < sum(a) <= 50                 # p=0.4 fired, max_fires capped
    assert sequence("s1g1") != a             # decorrelated, still seeded
    chaos.uninstall()
    assert chaos.fire("eval.hang") is None   # off = no-op


# --------------------------------------------------------------------- #
# fleet conformance under injected faults (both backends)
# --------------------------------------------------------------------- #
def test_crash_before_complete_trace_identical(broker, tmp_path):
    """Workers that die after evaluating but before completing lose
    their lease; the requeued jobs land on survivors and the campaign
    finishes bit-identical to the fault-free run."""
    broker.max_attempts = 6              # crashes burn lease attempts
    spec = SessionSpec(problem="toy_rastrigin", tuner="genetic", budget=40,
                       seed=3)
    store_ref = SessionStore(tmp_path / "ref")
    ref = run_session(spec, store=store_ref)

    chaos.install(_plan(FaultRule("worker.crash.before_complete", p=1.0,
                                  max_fires=2)))
    store_brk = SessionStore(tmp_path / "brk")
    # 3 thread workers share the process-global fire counter: exactly 2
    # die (ChaosCrash kills their serve loop), at least 1 survives
    stop, threads = _fleet(broker, n=3, lease_s=0.5)
    try:
        res = run_campaign([spec], store_brk,
                           broker=broker)[spec.session_id]
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=30)
    assert chaos.stats()["worker.crash.before_complete"]["fires"] == 2
    assert _traces_equal(ref, res)
    assert (store_ref._journal_path(spec.session_id).read_text()
            == store_brk._journal_path(spec.session_id).read_text())
    assert store_brk.meta(spec.session_id)["status"] == "done"


def test_heartbeat_stall_abandons_batch_and_requeues(broker, tmp_path,
                                                     monkeypatch):
    """A worker whose heartbeats stall past the lease is presumed dead:
    the job requeues onto a peer, and when the stalled worker wakes to a
    False heartbeat it *abandons* the doomed batch (recorded as an
    ``abandoned`` counter) instead of finishing work whose result would
    be rejected anyway."""
    monkeypatch.setitem(registry.TOY_FACTORIES, "toy_slow", _slow_problem)
    spec = SessionSpec(problem="toy_slow", tuner="random", budget=24,
                       seed=0, workers=8)
    ref = run_session(spec)

    chaos.install(_plan(FaultRule("worker.heartbeat.stall", p=1.0,
                                  max_fires=1, params={"stall_s": 0.8})))
    store = SessionStore(tmp_path / "store")
    stop, threads = _fleet(broker, n=2, lease_s=0.3)
    try:
        res = run_campaign([spec], store, broker=broker)[spec.session_id]
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=60)
    assert chaos.stats()["worker.heartbeat.stall"]["fires"] == 1
    assert _traces_equal(ref, res)
    abandoned = [s for s in broker.read_metrics()
                 if s["name"] == "abandoned"]
    assert abandoned, "stalled worker must record the abandoned batch"


def test_eval_hang_resolved_by_retry_is_trace_identical(broker, tmp_path):
    """One hung chunk trips the watchdog; the per-config retries succeed
    (the hang is spent) and the journaled trials carry no trace of the
    incident — bit-identical to the fault-free run."""
    spec = SessionSpec(problem="toy_rastrigin", tuner="random", budget=20,
                       seed=9)
    ref = run_session(spec)
    chaos.install(_plan(FaultRule("eval.hang", p=1.0, max_fires=1,
                                  params={"hang_s": 1.0})))
    store = SessionStore(tmp_path / "store")
    stop, threads = _fleet(broker, n=1, lease_s=5.0, job_timeout_s=0.25)
    try:
        res = run_campaign([spec], store, broker=broker)[spec.session_id]
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=60)
    assert chaos.stats()["eval.hang"]["fires"] == 1
    assert _traces_equal(ref, res)
    assert not any(t.info.get("timeout") for t in res.trials)
    assert (SessionStore(tmp_path / "store")
            ._journal_path(spec.session_id).exists())


def test_eval_hang_every_attempt_becomes_timeout_poison(broker, tmp_path):
    """A measurement that hangs on every attempt is poisoned by the
    watchdog — invalid trial, ``timeout: True`` info — journaled like
    any poison, so a resumed replay is info-identical."""
    spec = SessionSpec(problem="toy_quad", tuner="random", budget=4,
                       seed=1, workers=2)
    chaos.install(_plan(FaultRule("eval.hang", p=1.0,
                                  params={"hang_s": 1.0})))
    store = SessionStore(tmp_path / "store")
    stop, threads = _fleet(broker, n=1, lease_s=5.0, job_timeout_s=0.2)
    try:
        res = run_campaign([spec], store, broker=broker)[spec.session_id]
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=120)
    chaos.uninstall()                    # wake the injected sleepers
    assert len(res.trials) == 4
    for t in res.trials:
        assert not t.valid
        assert t.info.get("poison") and t.info.get("timeout") is True
        assert "timed out" in t.info.get("error", "")
    # the fleet recorded the watchdog fires durably
    assert any(s["name"] == "timeouts" for s in broker.read_metrics())
    # replay from the journal: info-identical (no re-evaluation happens —
    # chaos is disarmed, yet the timeout markers are all still there)
    res2 = run_session(spec, store=store)
    for a, b in zip(res.trials, res2.trials):
        assert a.info.get("timeout") == b.info.get("timeout")
        assert a.info.get("poison") == b.info.get("poison")
        assert a.info.get("attempts") == b.info.get("attempts")


def test_clock_skew_is_survivable(broker, tmp_path):
    """Occasional skewed lease-clock readings (NTP step, VM pause) well
    under the lease length never corrupt a campaign."""
    spec = SessionSpec(problem="toy_rastrigin", tuner="genetic", budget=30,
                       seed=5)
    ref = run_session(spec)
    chaos.install(_plan(FaultRule("broker.clock.skew", p=0.3,
                                  params={"skew_s": 1.0})))
    store = SessionStore(tmp_path / "store")
    stop, threads = _fleet(broker, n=2, lease_s=30.0)
    try:
        res = run_campaign([spec], store, broker=broker)[spec.session_id]
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=60)
    assert _traces_equal(ref, res)
    assert chaos.stats()["broker.clock.skew"]["fires"] > 0


def test_sqlite_busy_storm_absorbed_by_retry(tmp_path):
    """An injected lock storm (OperationalError on transaction entry) is
    absorbed by the broker's bounded busy-retry — the mutation lands."""
    broker = SQLiteBroker(tmp_path / "queue.db")
    chaos.install(_plan(FaultRule("broker.busy", p=1.0, max_fires=3)))
    jid = broker.submit({"problem": "toy_quad", "archs": ["v5e"],
                         "rows": [1], "sessions": []})
    assert jid == 1
    st = chaos.stats()["broker.busy"]
    assert st["fires"] == 3
    chaos.uninstall()
    got = broker.lease("w1", lease_s=30.0)
    assert got is not None and got[0] == jid
    broker.close()


# --------------------------------------------------------------------- #
# torn journal appends (store seam)
# --------------------------------------------------------------------- #
def test_torn_append_recovery_on_resume(tmp_path, caplog):
    """A crash mid-append leaves a genuinely torn final line.  The loss
    is surfaced (log + ``journal.torn_lines`` counter), never glued onto
    later appends, and resume redoes the lost batch — final trace equal
    to the never-crashed run."""
    spec = SessionSpec(problem="toy_rastrigin", tuner="genetic", budget=30,
                       seed=4)
    ref = run_session(spec)

    store = SessionStore(tmp_path / "store")
    chaos.install(_plan(FaultRule("journal.append.torn", p=1.0, after=2,
                                  max_fires=1, params={"frac": 0.5})))
    with pytest.raises(ChaosCrash):
        run_session(spec, store=store)
    chaos.uninstall()

    sid = spec.session_id
    assert store.meta(sid)["status"] == "failed"
    lines = store._journal_path(sid).read_text().splitlines()
    with pytest.raises(json.JSONDecodeError):
        json.loads(lines[-1])          # the tear is real

    tmetrics.enable()
    try:
        import logging
        with caplog.at_level(logging.WARNING, "repro.orchestrator.store"):
            res = run_session(spec, store=store)
        assert any("torn line" in r.message for r in caplog.records)
        torn = [s for s in tmetrics.snapshot()
                if s["name"] == "journal.torn_lines"]
        assert torn and torn[0]["value"] == 1
    finally:
        tmetrics.disable()
        tmetrics.reset()
    assert _traces_equal(ref, res)
    assert store.meta(sid)["status"] == "done"
    # the torn fragment is still physically there, on its own line —
    # later appends were never glued onto it
    lines = store._journal_path(sid).read_text().splitlines()
    bad = [ln for ln in lines if ln.strip()
           and not _parses(ln)]
    assert len(bad) == 1


def _parses(line: str) -> bool:
    try:
        json.loads(line)
        return True
    except json.JSONDecodeError:
        return False


# --------------------------------------------------------------------- #
# supervisor policy (fake processes, fake clock)
# --------------------------------------------------------------------- #
class _FakeProc:
    def __init__(self):
        self.rc = None
        self.pid = 12345
        self.terminated = False

    def poll(self):
        return self.rc

    def terminate(self):
        self.terminated = True
        self.rc = 0                    # drains instantly in fake land

    def kill(self):
        self.rc = -9

    def wait(self, timeout=None):
        if self.rc is None:
            raise TimeoutError
        return self.rc


def _fake_supervisor(broker, **kw):
    clk = {"t": 0.0}
    spawned = []

    def spawn(slot, worker_id):
        p = _FakeProc()
        spawned.append((slot.idx, worker_id, p))
        return p

    sup = FleetSupervisor(broker, spawn=spawn, clock=lambda: clk["t"], **kw)
    return sup, clk, spawned


def test_supervisor_scales_with_queue_depth():
    broker = MemoryBroker()
    sup, clk, spawned = _fake_supervisor(
        broker, min_workers=1, max_workers=3, scale_down_after_s=2.0)
    jids = [broker.submit({"problem": "toy_quad", "archs": ["v5e"],
                           "rows": [i], "sessions": []}) for i in range(5)]
    sup.tick()
    assert sup.target_size() == 3
    assert sum(s.alive() for s in sup.slots) == 3
    assert sup.events["spawns"] == 3

    # demand drains away: scale down only after the hold, one per tick
    for jid in jids:
        got = broker.lease(f"w{jid}", lease_s=30.0)
        broker.complete(got[0], f"w{jid}", {"arch_trials": {"v5e": []}})
    broker.collect()
    clk["t"] = 1.0
    sup.tick()
    assert sum(s.alive() for s in sup.slots) == 3   # still inside the hold
    clk["t"] = 4.0
    sup.tick()                         # marks + retires the youngest
    sup.tick()                         # reaps the retire, retires the next
    sup.tick()
    assert sum(s.alive() for s in sup.slots) == 1   # back to min
    assert sup.events["retires"] == 2
    # restarts/quarantines never fired — retires are not failures
    assert sup.events["restarts"] == 0
    # supervisor metrics landed in the broker's durable table
    names = {s["name"] for s in broker.read_metrics()}
    assert {"spawns", "fleet_size", "fleet_target"} <= names


def test_supervisor_backoff_doubles_then_quarantines():
    broker = MemoryBroker()
    broker.submit({"problem": "toy_quad", "archs": ["v5e"], "rows": [0],
                   "sessions": []})
    sup, clk, spawned = _fake_supervisor(
        broker, min_workers=1, max_workers=1, backoff_base_s=0.5,
        healthy_s=5.0, crash_loop_threshold=3, quarantine_s=60.0)
    slot = sup.slots[0]

    sup.tick()
    assert slot.alive() and sup.events["spawns"] == 1

    # crash #1 (fast): backoff 0.5s gates the respawn
    spawned[-1][2].rc = 1
    clk["t"] = 0.1
    sup.tick()
    assert slot.failures == 1 and not slot.alive()
    assert slot.next_spawn_at == pytest.approx(0.6)
    clk["t"] = 0.3
    sup.tick()
    assert not slot.alive()            # still backing off
    clk["t"] = 0.7
    sup.tick()
    assert slot.alive() and sup.events["spawns"] == 2

    # crash #2 (fast): backoff doubles to 1.0s
    spawned[-1][2].rc = 1
    clk["t"] = 0.8
    sup.tick()
    assert slot.failures == 2
    assert slot.next_spawn_at == pytest.approx(1.8)
    clk["t"] = 1.9
    sup.tick()
    assert slot.alive() and sup.events["spawns"] == 3

    # crash #3: the loop threshold — quarantine, streak reset
    spawned[-1][2].rc = 1
    clk["t"] = 2.0
    sup.tick()
    assert sup.events["quarantines"] == 1
    assert slot.failures == 0
    assert slot.quarantined_until == pytest.approx(62.0)
    clk["t"] = 30.0
    sup.tick()
    assert not slot.alive()            # quarantine holds
    clk["t"] = 62.5
    sup.tick()
    assert slot.alive() and sup.events["spawns"] == 4
    assert sup.events["restarts"] == 3

    # a healthy stretch resets the streak: next crash counts as #1 again
    spawned[-1][2].rc = 1
    clk["t"] = 70.0                    # uptime 7.5s >= healthy_s
    sup.tick()
    assert slot.failures == 1


def test_supervisor_run_drains_on_empty_queue():
    broker = MemoryBroker()
    sup, clk, spawned = _fake_supervisor(broker, min_workers=1,
                                         max_workers=2, interval_s=0.01,
                                         drain_grace_s=0.5)
    events = sup.run(drain_on_empty_s=0.0)
    assert events["spawns"] >= 1
    assert all(not s.alive() for s in sup.slots)
    assert all(p.terminated for _, _, p in spawned)


def test_supervisor_needs_file_backed_broker_for_default_spawn():
    sup = FleetSupervisor(MemoryBroker(), min_workers=1, max_workers=1)
    MemoryBroker().submit({"problem": "toy_quad", "archs": ["v5e"],
                           "rows": [0], "sessions": []})
    with pytest.raises(ValueError, match="file-backed"):
        sup._spawn_subprocess(sup.slots[0], "w0")


# --------------------------------------------------------------------- #
# graceful drain: a real subprocess finishes its in-flight job
# --------------------------------------------------------------------- #
def test_sigterm_drains_worker_midjob(tmp_path):
    """SIGTERM while a real worker process provably holds a lease: the
    worker finishes the job (made slow by an injected eval hang),
    completes it at the broker, and exits 0 — nothing requeues."""
    db = str(tmp_path / "queue.db")
    broker = SQLiteBroker(db)
    jid = broker.submit({"problem": "toy_quad", "pk": {}, "archs": ["v5e"],
                         "rows": [0, 1, 2], "sessions": []})
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    plan = json.dumps({"seed": 1, "faults": [
        {"site": "eval.hang", "p": 1.0, "max_fires": 1, "hang_s": 2.0}]})
    log = tmp_path / "worker.log"
    with open(log, "w") as lf:
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.orchestrator", "worker",
             "--broker", db, "--workers", "2", "--lease", "30",
             "--poll", "0.02", "--max-idle", "60", "--chaos", plan],
            env=env, stdout=lf, stderr=lf, cwd=str(tmp_path))
    try:
        deadline = time.time() + 60
        while not broker.in_flight():
            assert time.time() < deadline, "worker never leased the job"
            assert proc.poll() is None, "worker died before leasing"
            time.sleep(0.01)
        proc.terminate()               # SIGTERM mid-hang
        rc = proc.wait(timeout=60)
    finally:
        proc.kill()
        proc.wait(timeout=30)
    assert rc == 0
    done, failed = broker.collect()
    assert [j for j in done] == [jid] and not failed
    assert "draining" in log.read_text()
    broker.close()


# --------------------------------------------------------------------- #
# doctor: the offline integrity check that catches all of the above
# --------------------------------------------------------------------- #
def test_doctor_clean_store_and_broker(tmp_path, capsys):
    store = SessionStore(tmp_path / "store")
    spec = SessionSpec(problem="toy_quad", tuner="random", budget=10, seed=0)
    run_session(spec, store=store)
    db = str(tmp_path / "queue.db")
    SQLiteBroker(db).close()
    rc = cli_main(["doctor", "--store", str(store.root), "--broker", db])
    out = capsys.readouterr().out
    assert rc == 0
    assert "no problems found" in out


def test_doctor_flags_torn_running_unpublished_and_stale(tmp_path, capsys):
    store = SessionStore(tmp_path / "store")
    # torn journal + running-with-no-lease
    s1 = SessionSpec(problem="toy_quad", tuner="random", budget=10, seed=0)
    run_session(s1, store=store, stop_after=4)
    store.update_meta(s1.session_id, status="running")
    with open(store._journal_path(s1.session_id), "a") as f:
        f.write('{"k": 3, "o": 0.5, "v": tr')       # torn tail
    # done-but-unpublished: marked done without ever publishing a table
    s2 = SessionSpec(problem="toy_quad", tuner="random", budget=10, seed=1)
    store.create(s2)
    store.update_meta(s2.session_id, status="done")
    # a stale lease on the broker
    broker = SQLiteBroker(tmp_path / "queue.db")
    broker.submit({"problem": "toy_quad", "archs": ["v5e"], "rows": [0],
                   "sessions": [s1.session_id]})
    broker.lease("w-dead", lease_s=0.01)
    time.sleep(0.05)

    report = diagnose(store, broker)
    assert not report["ok"]
    text = "\n".join(report["problems"])
    assert "torn journal line" in text
    assert "never published" in text
    assert "lease expired" in text
    # s1 *is* carried by the (stale) lease, so no "no live lease" flag;
    # popping it would: doctor is read-only, so fake it by reaping
    broker.reap()
    report2 = diagnose(store, broker)
    assert any("no live lease" in p for p in report2["problems"])

    rc = cli_main(["doctor", "--store", str(store.root),
                   "--broker", str(tmp_path / "queue.db"), "--json"])
    out = capsys.readouterr().out
    assert rc == 1
    parsed = json.loads(out)
    assert parsed["ok"] is False and parsed["problems"]
    broker.close()


def test_doctor_published_check_survives_kernel_name_mismatch(tmp_path):
    """Traces are keyed by the problem's *kernel* name, which differs
    from the registry name for attention (flash_attention) — doctor must
    match the session-unique protocol tag, not guess the table key."""
    store = SessionStore(tmp_path / "store")
    spec = SessionSpec(problem="attention", tuner="random", budget=6,
                       seed=0)
    run_session(spec, store=store)
    report = diagnose(store)
    entry = next(e for e in report["sessions"]
                 if e["session"] == spec.session_id)
    assert entry["status"] == "done" and entry["published"]
    assert not any("never published" in p for p in report["problems"])


def test_doctor_refuses_missing_broker_db(tmp_path, capsys):
    store = SessionStore(tmp_path / "store")
    missing = tmp_path / "nope" / "queue.db"
    rc = cli_main(["doctor", "--store", str(store.root),
                   "--broker", str(missing)])
    assert rc == 2
    assert "no broker db" in capsys.readouterr().err
    assert not missing.exists()
