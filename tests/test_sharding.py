"""Sharding-rule unit tests (single device: rules are pure functions of
shapes + mesh topology, so they are fully testable without 256 chips)."""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as shd


def _fake_mesh(shape, axes):
    """Mesh over *abstract* devices — spec_for/batch_spec only read the
    topology, so single-host construction suffices via mock device arrays."""
    n = int(np.prod(shape))
    devs = np.array([jax.devices("cpu")[0]] * n, dtype=object).reshape(shape)
    return Mesh(devs, axes)


MESH = _fake_mesh((16, 16), ("data", "model"))
MESH3 = _fake_mesh((2, 16, 16), ("pod", "data", "model"))


def test_spec_for_prefers_model_axes_in_order():
    # vocab beats heads for the model axis
    spec = shd.spec_for((151_936, 4096), ("vocab", "embed"), MESH)
    assert spec == P("model", "data")
    # heads divisible: model on heads; data falls to embed
    spec = shd.spec_for((4096, 32, 128), ("embed", "heads", "head_dim"), MESH)
    assert spec == P("data", "model", None)


def test_spec_for_divisibility_fallback():
    """56 heads don't divide model=16 -> TP falls through to head_dim."""
    spec = shd.spec_for((7168, 56, 128), ("embed", "heads", "head_dim"), MESH)
    assert spec == P("data", None, "model")
    # 8 kv heads don't divide 16 either
    spec = shd.spec_for((4096, 8, 128), ("embed", "kv_heads", "head_dim"),
                        MESH)
    assert spec == P("data", None, "model")


def test_spec_for_expert_sharding():
    spec = shd.spec_for((160, 5120, 1536), ("expert", "embed", "ff"), MESH)
    assert spec == P("model", "data", None)


def test_batch_spec_full_data_parallel():
    assert shd.batch_spec((256, 4096), MESH) == P("data", None)
    assert shd.batch_spec((256, 4096), MESH3) == P(("pod", "data"), None)


def test_batch_spec_sequence_fallback_long_context():
    """batch=1 (long_500k): the sequence axis takes the data shard."""
    spec = shd.batch_spec((1, 524_288), MESH)
    assert spec == P(None, "data")
    spec3 = shd.batch_spec((1, 524_288), MESH3)
    assert spec3 == P(None, ("pod", "data"))


def test_batch_spec_pod_spillover():
    """batch divisible by data but not pod*data: sequence takes the pod."""
    spec = shd.batch_spec((16, 4096), MESH3)
    assert spec[0] == "data" and spec[1] == "pod"


def test_param_shardings_tree_alignment():
    from repro.configs import ARCHS, reduce_config
    from repro.models import build_model
    cfg = reduce_config(ARCHS["qwen3-8b"])
    model = build_model(cfg)
    abstract = model.abstract_params()
    if model.axes is None:
        jax.eval_shape(model.init, jax.random.key(0))
    sh = shd.param_shardings(abstract, model.axes, MESH)
    flat_p = jax.tree.leaves(abstract)
    flat_s = jax.tree.leaves(
        sh, is_leaf=lambda v: isinstance(v, jax.sharding.NamedSharding))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        # every sharded dim must divide evenly
        spec = tuple(s.spec) + (None,) * (len(p.shape) - len(tuple(s.spec)))
        sizes = dict(zip(MESH.axis_names, MESH.devices.shape))
        for dim, name in enumerate(spec):
            if name is None:
                continue
            names = name if isinstance(name, tuple) else (name,)
            k = int(np.prod([sizes[n] for n in names]))
            assert p.shape[dim] % k == 0, (p.shape, spec)


def test_constrain_is_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, "data", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_cache_shardings_decode_cell():
    """decode_32k: batch 128 -> (pod,data) infeasible (128 % 256 != 0 on the
    flat axis? it is 128 % 16 == 0 for data) ... the rule must place data on
    batch when divisible and model on kv-head-like dims."""
    cache = {"k": jax.ShapeDtypeStruct((128, 32_768, 8, 128), jnp.bfloat16)}
    sh = shd.cache_shardings(cache, MESH, n_kv_heads=8, batch=128)
    spec = tuple(sh["k"].spec)
    assert spec[0] == "data"
    # one of the trailing dims may carry "model"; all shards must divide
    sizes = dict(zip(MESH.axis_names, MESH.devices.shape))
    shape = (128, 32_768, 8, 128)
    for dim, name in enumerate(spec):
        if name is None:
            continue
        names = name if isinstance(name, tuple) else (name,)
        k = int(np.prod([sizes[n] for n in names]))
        assert shape[dim] % k == 0
