"""servedb contract tests: atomic publish, quarantine, the degradation
chain's ordering/determinism, hot reload, distillation, and the shared
retry policy."""

from __future__ import annotations

import json
import math
import threading

import pytest

from repro.core.retry import RetryBudgetExceeded, backoff_delays, retry_call
from repro.orchestrator import chaos
from repro.orchestrator.runner import run_session
from repro.orchestrator.session import SessionSpec
from repro.orchestrator.store import SessionStore
from repro.servedb import (STATIC_DEFAULTS, ServeDB, Snapshot, TIERS,
                           default_config)
from repro.servedb import snapshot as snap_mod
from repro.servedb.distill import build_snapshot, load_binary
from repro.servedb.lookup import _best_entry
from repro.servedb.snapshot import (SNAPSHOT_NAME, load, publish, shape_key,
                                    shape_distance, verify_dir)


@pytest.fixture(autouse=True)
def _no_chaos():
    yield
    chaos.uninstall()


def _entry(shape, config, objective=1.0, protocol="session_x"):
    return {"shape": shape, "config": config, "objective": objective,
            "protocol": protocol, "trials": 10}


def _snap(entries=None, heuristic=None, ttl_s=None):
    group = {"param_names": ["a", "b"], "heuristic": heuristic,
             "entries": entries or []}
    return Snapshot(tables={"k": {"v5e": group}}, ttl_s=ttl_s)


# --------------------------------------------------------------------- #
# snapshot grammar + atomic publish
# --------------------------------------------------------------------- #
def test_publish_load_roundtrip(tmp_path):
    snap = _snap([_entry({"n": 64}, {"a": 1, "b": 2})])
    path = publish(snap, tmp_path)
    assert path.name == SNAPSHOT_NAME
    got, problems = load(tmp_path)
    assert problems == []
    assert got.generation == 1
    assert got.tables == snap_mod._canonical_tables(snap.tables)
    # republish bumps the generation, entries unchanged
    publish(snap, tmp_path)
    got2, _ = load(tmp_path)
    assert got2.generation == 2
    assert got2.tables == got.tables


def test_publish_is_byte_deterministic(tmp_path):
    a = _snap([_entry({"n": 64}, {"a": 1}), _entry({"n": 8}, {"a": 2})])
    b = _snap([_entry({"n": 8}, {"a": 2}), _entry({"n": 64}, {"a": 1})])
    a.generation = b.generation = 3
    a.created_at = b.created_at = 123.0
    assert a.to_bytes() == b.to_bytes()


@pytest.mark.parametrize("corrupter", [
    lambda raw: raw[: len(raw) // 2],                       # truncation
    lambda raw: raw[:50] + bytes([raw[50] ^ 0x20]) + raw[51:],  # bitflip
    lambda raw: b"not json at all",
    lambda raw: b'{"header": {"magic": "something-else"}}',
])
def test_corrupt_snapshot_quarantines_without_raising(tmp_path, corrupter):
    publish(_snap([_entry({}, {"a": 1})]), tmp_path)
    p = tmp_path / SNAPSHOT_NAME
    p.write_bytes(corrupter(p.read_bytes()))
    got, problems = load(tmp_path)          # must not raise
    assert got is None
    assert problems and "quarantined" in problems[0]
    assert not p.exists()                   # moved aside, never re-parsed
    qdir = tmp_path / "quarantine"
    assert list(qdir.glob("*.bad"))
    report = verify_dir(tmp_path)
    assert not report["ok"]
    assert report["quarantined"]


def test_binary_checksum_failure_disables_binary_only(tmp_path):
    snap = _snap([_entry({}, {"a": 1})])
    publish(snap, tmp_path, binary_bytes=b"not-an-npz-but-checksummed")
    # corrupt the npz, not the JSON
    binpath = next(tmp_path.glob("tables-g*.npz"))
    binpath.write_bytes(b"rotted")
    got, problems = load(tmp_path)
    assert got is not None                  # JSON tables still serve
    assert got.binary is None               # binary disabled
    assert problems and "binary" in problems[0]


def test_crash_between_temp_and_rename_preserves_old_snapshot(tmp_path):
    publish(_snap([_entry({}, {"a": 1})]), tmp_path)
    before = (tmp_path / SNAPSHOT_NAME).read_bytes()
    chaos.install(chaos.FaultPlan(seed=3, rules=[
        chaos.FaultRule("servedb.publish.crash", p=1.0, max_fires=1)]))
    with pytest.raises(BaseException):
        publish(_snap([_entry({}, {"a": 2})]), tmp_path)
    # the live snapshot is byte-for-byte the old one; the temp artifact
    # is diagnosable and the next publish succeeds
    assert (tmp_path / SNAPSHOT_NAME).read_bytes() == before
    report = verify_dir(tmp_path)
    assert any("temp" in p for p in report["problems"])
    publish(_snap([_entry({}, {"a": 2})]), tmp_path)
    got, problems = load(tmp_path)
    assert problems == []
    assert got.tables["k"]["v5e"]["entries"][0]["config"] == {"a": 2}


def test_corrupt_site_truncate_and_bitflip_are_detected(tmp_path):
    for i, mode in enumerate(("truncate", "bitflip")):
        root = tmp_path / mode
        chaos.install(chaos.FaultPlan(seed=i, rules=[
            chaos.FaultRule("servedb.snapshot.corrupt", p=1.0, max_fires=1,
                            params={"mode": mode, "frac": 0.5})]))
        publish(_snap([_entry({}, {"a": 1})]), root)
        chaos.uninstall()
        got, problems = load(root)
        assert got is None
        assert problems and "quarantined" in problems[0]


def test_publish_lock_serializes_and_breaks_dead_holders(tmp_path):
    # a live contender: publishes serialize, both land
    snap = _snap([_entry({}, {"a": 1})])
    errs = []

    def contend():
        try:
            publish(snap, tmp_path)
        except Exception as e:              # pragma: no cover
            errs.append(e)

    ts = [threading.Thread(target=contend) for _ in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert not errs
    got, _ = load(tmp_path)
    assert got.generation == 4
    # a dead holder's lock is broken immediately (pid no longer exists)
    lock = tmp_path / "publish.lock"
    lock.write_text("999999999\n")
    publish(snap, tmp_path)
    assert load(tmp_path)[0].generation == 5


# --------------------------------------------------------------------- #
# the degradation chain
# --------------------------------------------------------------------- #
def _chain_db(tmp_path, **kw):
    snap = _snap(
        entries=[_entry({"n": 64}, {"a": 1, "b": 1}, objective=0.5),
                 _entry({"n": 256}, {"a": 2, "b": 2}, objective=0.7)],
        heuristic={"a": 9, "b": 9}, **kw)
    snap.tables["k"]["v4"] = {
        "param_names": ["a", "b"], "heuristic": None,
        "entries": [_entry({"n": 64}, {"a": 7, "b": 7})]}
    publish(snap, tmp_path)
    return ServeDB(tmp_path, use_cost_model=False, reload_every_s=0.0)


def test_chain_order_exact_nearest_heuristic_default(tmp_path):
    db = _chain_db(tmp_path)
    r = db.lookup("k", {"n": 64}, "v5e")
    assert (r.tier, r.config) == ("exact", {"a": 1, "b": 1})
    assert not r.degraded()
    r = db.lookup("k", {"n": 96}, "v5e")    # log2-nearer to 64 than 256
    assert (r.tier, r.config) == ("nearest", {"a": 1, "b": 1})
    assert r.matched_shape == {"n": 64} and r.distance > 0
    r = db.lookup("k", {"n": 300}, "v6e")   # arch absent -> cross-arch
    assert r.tier == "heuristic"
    assert r.detail == "heuristic:cross-arch:v4"
    assert r.config == {"a": 7, "b": 7}
    r = db.lookup("unknown_kernel", {}, "v5e")
    assert (r.tier, r.config) == ("default", {})
    assert db.lookup("gemm", {}, "v5e").config == STATIC_DEFAULTS["gemm"]
    # the per-tier counters saw every answer
    counts = db.tier_counts()
    assert counts["exact"] == 1 and counts["nearest"] == 1
    assert counts["heuristic"] == 1 and counts["default"] == 2


def test_chain_heuristic_distilled_beats_default(tmp_path):
    # an arch group with a heuristic but no entries: heuristic tier
    snap = _snap(entries=[], heuristic={"a": 9, "b": 9})
    publish(snap, tmp_path)
    db = ServeDB(tmp_path, use_cost_model=False, reload_every_s=0.0)
    r = db.lookup("k", {"n": 1}, "v5e")
    assert (r.tier, r.detail) == ("heuristic", "heuristic:distilled")
    assert r.config == {"a": 9, "b": 9}


def test_nearest_is_deterministic_under_ties():
    # two entries equidistant from the query: the smaller shape key wins,
    # stably, regardless of list order
    e1 = _entry({"n": 32}, {"a": 1})
    e2 = _entry({"n": 128}, {"a": 2})
    q = {"n": 64}
    assert shape_distance(q, e1["shape"]) == shape_distance(q, e2["shape"])
    for entries in ([e1, e2], [e2, e1]):
        e, d = _best_entry(entries, q)
        assert e["config"] == {"a": 2}      # {"n":128} sorts before {"n":32}
        assert shape_key(e["shape"]) == min(shape_key(e1["shape"]),
                                            shape_key(e2["shape"]))


def test_shape_distance_is_log_scaled_and_total():
    assert shape_distance({"n": 64}, {"n": 64}) == 0.0
    assert shape_distance({"n": 64}, {"n": 128}) \
        < shape_distance({"n": 64}, {"n": 1024})
    # missing/non-numeric dims cost a fixed penalty, never raise
    assert shape_distance({"n": 64}, {"m": 64}) > 30
    assert math.isfinite(shape_distance({"n": "x"}, {"n": 64}))


def test_lookup_never_raises_even_on_internal_error(tmp_path):
    db = ServeDB(tmp_path / "nonexistent", use_cost_model=False,
                 reload_every_s=3600.0)
    r = db.lookup("k", {"n": 1}, "v5e")
    assert r.tier == "default"
    # poison the snapshot attribute outright: the chain's own failure
    # still answers from the floor
    db._snapshot = object()
    r = db.lookup("k", {"n": 1}, "v5e")
    assert r.tier == "default" and "chain-error" in r.detail


def test_stale_snapshot_degrades_and_flags(tmp_path):
    snap = _snap([_entry({"n": 64}, {"a": 1, "b": 1})], ttl_s=0.0)
    snap.created_at = 1.0                   # long past its ttl
    publish(snap, tmp_path)
    db = ServeDB(tmp_path, use_cost_model=False, reload_every_s=0.0)
    r = db.lookup("k", {"n": 64}, "v5e")
    assert r.stale and r.tier == "default"  # tables skipped
    # serve_stale: the hit is served, still flagged
    db2 = ServeDB(tmp_path, use_cost_model=False, reload_every_s=0.0,
                  serve_stale=True)
    r2 = db2.lookup("k", {"n": 64}, "v5e")
    assert r2.stale and r2.tier == "exact"
    assert verify_dir(tmp_path)["snapshots"][0]["status"] == "stale"


# --------------------------------------------------------------------- #
# hot reload
# --------------------------------------------------------------------- #
def test_hot_reload_unchanged_snapshot_is_bit_identical(tmp_path):
    db = _chain_db(tmp_path)
    queries = [("k", {"n": 64}, "v5e"), ("k", {"n": 96}, "v5e"),
               ("k", {"n": 1}, "v6e"), ("zzz", {}, "v5e")]
    before = [db.lookup(*q) for q in queries]
    # rewrite the identical bytes (mtime changes, content does not)
    p = tmp_path / SNAPSHOT_NAME
    raw = p.read_bytes()
    p.write_bytes(raw)
    assert db.reload(force=True) is False   # same generation: no swap event
    after = [db.lookup(*q) for q in queries]
    for b, a in zip(before, after):
        assert (b.config, b.tier, b.detail, b.generation) \
            == (a.config, a.tier, a.detail, a.generation)


def test_hot_reload_picks_up_new_generation(tmp_path):
    db = _chain_db(tmp_path)
    assert db.lookup("k", {"n": 64}, "v5e").config == {"a": 1, "b": 1}
    snap = _snap([_entry({"n": 64}, {"a": 5, "b": 5})])
    publish(snap, tmp_path)
    assert db.reload(force=True) is True
    r = db.lookup("k", {"n": 64}, "v5e")
    assert r.config == {"a": 5, "b": 5} and r.generation == 2


def test_hot_reload_corrupt_replacement_keeps_serving_old(tmp_path):
    db = _chain_db(tmp_path)
    before = db.lookup("k", {"n": 64}, "v5e")
    p = tmp_path / SNAPSHOT_NAME
    p.write_bytes(p.read_bytes()[:100])     # torn replacement lands
    db.reload(force=True)
    assert db.problems()                    # detected + quarantined...
    after = db.lookup("k", {"n": 64}, "v5e")
    assert (after.tier, after.config) == (before.tier, before.config)
    # ...and an intact republish restores bit-identical lookups
    _chain_db(tmp_path)                     # republish same tables
    db.reload(force=True)
    restored = db.lookup("k", {"n": 64}, "v5e")
    assert (restored.tier, restored.config, restored.detail) \
        == (before.tier, before.config, before.detail)


# --------------------------------------------------------------------- #
# distillation from a real campaign store
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def toy_store(tmp_path_factory):
    root = tmp_path_factory.mktemp("store")
    store = SessionStore(root)
    for problem, arch in (("toy_quad", "v5e"), ("toy_quad", "v4"),
                          ("toy_rastrigin", "v5e")):
        spec = SessionSpec(problem=problem, tuner="random", arch=arch,
                           budget=16, seed=0, workers=2)
        store.create(spec)
        run_session(spec, store=store, mode="thread")
    return root


def test_distill_serves_campaign_best(toy_store, tmp_path):
    snap, binary, problems = build_snapshot(toy_store)
    assert problems == []
    assert snap.kernels() == ["toy_quad", "toy_rastrigin"]
    publish(snap, tmp_path, binary_bytes=binary)
    db = ServeDB(tmp_path, use_cost_model=False, reload_every_s=0.0)
    store = SessionStore(toy_store)
    for kernel, arch in (("toy_quad", "v5e"), ("toy_quad", "v4"),
                         ("toy_rastrigin", "v5e")):
        r = db.lookup(kernel, {}, arch)
        assert r.tier == "exact"
        sid = r.detail[len("session_"):]
        table = store.tables.get(kernel, arch, r.detail)
        best_cfg, best_obj = table.best()
        assert r.objective == best_obj
        spec = store.load_spec(sid)
        assert spec.arch == arch


def test_binary_export_roundtrips_to_json_configs(toy_store, tmp_path):
    snap, binary, _ = build_snapshot(toy_store)
    assert binary is not None
    publish(snap, tmp_path, binary_bytes=binary)
    loaded, problems = load(tmp_path)
    assert problems == [] and loaded.binary is not None
    bins = load_binary(tmp_path, loaded)
    assert bins is not None
    for kernel, archs in loaded.tables.items():
        for arch, group in archs.items():
            entries = group["entries"]      # already in canonical order
            got = bins[kernel][arch]
            assert got["configs"] == [e["config"] for e in entries]
            assert list(got["objectives"]) == \
                [e["objective"] for e in entries]
            assert got["shapes"] == [shape_key(e["shape"]) for e in entries]


def test_distill_keeps_best_across_sessions(toy_store, tmp_path):
    # a second, bigger-budget session for the same cell must win iff
    # it finds a strictly better objective
    store = SessionStore(toy_store)
    spec = SessionSpec(problem="toy_quad", tuner="genetic", arch="v5e",
                       budget=48, seed=1, workers=2)
    store.create(spec)
    run_session(spec, store=store, mode="thread")
    snap, _, problems = build_snapshot(toy_store, with_binary=False)
    assert problems == []
    entries = snap.tables["toy_quad"]["v5e"]["entries"]
    assert len(entries) == 1                # one shape cell, best-of kept
    objs = [math.inf]
    for kernel, arch, protocol in store.tables.list_tables():
        if kernel == "toy_quad" and arch == "v5e":
            objs.append(store.tables.get(kernel, arch, protocol).best()[1])
    assert entries[0]["objective"] == min(objs)


def test_list_tables_inverts_the_naming_scheme(toy_store):
    store = SessionStore(toy_store)
    keys = store.tables.list_tables()
    assert keys == sorted(keys)
    for problem, arch, protocol in keys:
        assert store.tables.has(problem, arch, protocol)
        t = store.tables.get(problem, arch, protocol)
        assert (t.problem, t.arch) == (problem, arch)


# --------------------------------------------------------------------- #
# static defaults stay valid configs
# --------------------------------------------------------------------- #
def test_static_defaults_are_valid_at_default_shapes():
    jax = pytest.importorskip("jax")        # noqa: F841 — kernel stack
    from repro.orchestrator.registry import make_problem
    from repro.servedb.distill import REGISTRY_NAME
    for kernel, cfg in STATIC_DEFAULTS.items():
        problem = make_problem(REGISTRY_NAME[kernel])
        space = problem.space
        assert set(cfg) == set(space.param_names), kernel
        space.encode(cfg)                   # every value in its alphabet
        assert space.satisfies(cfg), \
            f"{kernel} default violates a constraint: {cfg}"
    assert default_config("no_such_kernel") == {}


# --------------------------------------------------------------------- #
# the shared retry policy
# --------------------------------------------------------------------- #
def test_backoff_delays_bounded_and_deterministic():
    a = list(backoff_delays(6, base_s=0.01, max_s=0.2, salt="x"))
    b = list(backoff_delays(6, base_s=0.01, max_s=0.2, salt="x"))
    assert a == b                           # replayable
    assert len(a) == 6
    raw = [0.01, 0.02, 0.04, 0.08, 0.16, 0.2]
    for got, cap in zip(a, raw):
        assert cap * 0.5 <= got <= cap      # jitter scales in [1-j, 1]
    assert a != list(backoff_delays(6, base_s=0.01, max_s=0.2, salt="y"))
    plain = list(backoff_delays(6, base_s=0.01, max_s=0.2, jitter=0.0))
    assert plain == raw                     # jitter=0: capped doubling


def test_retry_call_budget_and_predicate():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise TimeoutError("busy")
        return "ok"

    slept = []
    assert retry_call(flaky, retries=5,
                      retry_on=lambda e: isinstance(e, TimeoutError),
                      sleep=slept.append) == "ok"
    assert len(calls) == 3 and len(slept) == 2
    # a non-transient error propagates immediately, unretried
    calls.clear()
    with pytest.raises(ValueError):
        retry_call(lambda: (_ for _ in ()).throw(ValueError("no")),
                   retries=5, retry_on=lambda e: False, sleep=slept.append)
    # exhausted budget with `what`: the summary error names the operation
    with pytest.raises(RetryBudgetExceeded, match="the op"):
        retry_call(lambda: (_ for _ in ()).throw(TimeoutError("busy")),
                   retries=2, retry_on=lambda e: True, what="the op",
                   sleep=lambda s: None)


# --------------------------------------------------------------------- #
# doctor + CLI integration
# --------------------------------------------------------------------- #
def test_doctor_triages_servedb(toy_store, tmp_path, capsys):
    from repro.orchestrator.cli import main as cli_main
    snap, binary, _ = build_snapshot(toy_store)
    publish(snap, tmp_path, binary_bytes=binary)
    assert cli_main(["doctor", "--store", str(toy_store),
                     "--servedb", str(tmp_path), "--json"]) == 0
    report = json.loads(capsys.readouterr().out)
    assert report["servedb"]["ok"]
    assert report["servedb"]["snapshots"][0]["status"] == "ok"
    # corrupt it: doctor flags, exit 1, one verdict line rendered
    p = tmp_path / SNAPSHOT_NAME
    p.write_bytes(p.read_bytes()[:-40])
    assert cli_main(["doctor", "--store", str(toy_store),
                     "--servedb", str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "CORRUPT" in out
    assert p.exists()                       # doctor is read-only


def test_cli_servedb_build_query_verify(toy_store, tmp_path, capsys):
    from repro.orchestrator.cli import main as cli_main
    db = str(tmp_path / "db")
    assert cli_main(["servedb", "build", "--store", str(toy_store),
                     "--db", db]) == 0
    capsys.readouterr()
    assert cli_main(["servedb", "query", "--db", db, "--kernel", "toy_quad",
                     "--arch", "v5e", "--json"]) == 0
    res = json.loads(capsys.readouterr().out)
    assert res["tier"] == "exact" and res["generation"] == 1
    assert cli_main(["servedb", "verify", "--db", db]) == 0
    capsys.readouterr()
    # degraded-but-alive: corrupt, query still answers, verify exits 1
    from pathlib import Path
    sp = Path(db) / SNAPSHOT_NAME
    sp.write_bytes(sp.read_bytes()[: 80])
    assert cli_main(["servedb", "query", "--db", db, "--kernel", "toy_quad",
                     "--arch", "v5e", "--json"]) == 0
    res = json.loads(capsys.readouterr().out)
    assert res["tier"] in TIERS and res["tier"] != "exact"
    assert cli_main(["servedb", "verify", "--db", db]) == 1
    capsys.readouterr()
    # build needs --store; query needs --kernel
    assert cli_main(["servedb", "build", "--db", db]) == 2
    assert cli_main(["servedb", "query", "--db", db]) == 2
