"""Multi-device integration tests.

jax locks the device count at first backend init, so every case here runs in
a fresh subprocess with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
— the same mechanism the 512-way dry-run uses, scaled to test size.
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _run(body: str, devices: int = 8, timeout: int = 420):
    prog = ("import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(body))
    env = dict(os.environ,
               PYTHONPATH=f"{REPO / 'src'}:{os.environ.get('PYTHONPATH', '')}")
    r = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                       text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    """One real sharded train step on a 4x2 mesh == the single-device step
    (bitwise-tolerant): the SPMD partition must not change the math."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import ARCHS, reduce_config
    from repro.data import DataConfig, make_pipeline
    from repro.distributed import sharding as shd
    from repro.launch.steps import make_train_step
    from repro.models import build_model
    from repro.train.optimizer import OptimizerConfig, init_opt_state

    cfg = reduce_config(ARCHS["qwen3-8b"])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    opt_cfg = OptimizerConfig(lr=1e-3)
    opt = init_opt_state(opt_cfg, params)
    batch = make_pipeline(DataConfig(vocab=cfg.vocab, seq_len=32,
                                     global_batch=8)).batch_at(0)
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    step = make_train_step(model, opt_cfg, microbatches=1)

    # single device reference
    p1, o1, m1 = jax.jit(step)(params, opt, batch)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    if model.axes is None:
        jax.eval_shape(model.init, jax.random.key(0))
    p_sh = shd.param_shardings(jax.eval_shape(lambda: params), model.axes,
                               mesh)
    with shd.use_mesh(mesh):
        params_s = jax.device_put(params, p_sh)
        opt_s = init_opt_state(opt_cfg, params_s)
        b_sh = {k: jax.NamedSharding(mesh, shd.batch_spec(v.shape, mesh))
                for k, v in batch.items()}
        batch_s = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
        p2, o2, m2 = jax.jit(step)(params_s, opt_s, batch_s)

    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-2, \
        (float(m1["loss"]), float(m2["loss"]))
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    worst = max(jax.tree.leaves(d))
    assert worst < 5e-2, worst
    print("OK sharded==single loss", float(m1["loss"]))
    """)


def test_gpipe_pipeline_matches_serial():
    """pipeline_apply over a 4-stage mesh == applying the 4 stage fns
    serially; also checks grad flows through ppermute."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import bubble_fraction, pipeline_apply

    mesh = jax.make_mesh((4, 2), ("stage", "data"))
    S, NM, MB, D = 4, 8, 4, 16
    ks = jax.random.split(jax.random.key(0), S)
    Ws = jnp.stack([jax.random.normal(k, (D, D)) * 0.3 for k in ks])

    def stage_fn(w, x):
        return jnp.tanh(x @ w)

    apply = pipeline_apply(stage_fn, mesh, axis="stage")
    x = jax.random.normal(jax.random.key(1), (NM, MB, D))
    got = jax.jit(apply)(Ws, x)

    want = x
    for s in range(S):
        want = stage_fn(Ws[s], want)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

    # differentiability (backward pipeline via ppermute transpose)
    def loss(Ws):
        return jnp.sum(apply(Ws, x) ** 2)
    g = jax.grad(loss)(Ws)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).max()) > 0
    assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
    print("OK pipeline")
    """)


def test_dryrun_cell_on_8_devices():
    """The dry-run driver machinery end-to-end on a small mesh: lower,
    compile, cost-analyse a reduced arch (proves plan_cell/lower_cell are
    mesh-size agnostic)."""
    _run("""
    import jax
    from repro.configs import ARCHS, reduce_config
    from repro.launch.steps import input_specs, lower_cell, plan_cell
    from repro.roofline import analyze_compiled

    import dataclasses
    cfg = reduce_config(ARCHS["granite-moe-3b-a800m"])
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    plan = plan_cell(cfg, "train_4k", mesh, microbatches=1)
    lowered = lower_cell(plan, mesh)
    compiled = lowered.compile()
    rep = analyze_compiled(compiled, chips=8, arch="granite-red",
                           shape="train_4k", mesh="4x2",
                           model_flops_value=1.0)
    assert rep.flops_per_chip > 0
    mem = compiled.memory_analysis()
    assert mem.temp_size_in_bytes >= 0
    print("OK dryrun-small", rep.bound)
    """, timeout=600)


def test_elastic_checkpoint_across_mesh_shapes():
    """Save params sharded on a 4x2 mesh, restore onto 2x4 — the elastic
    restart story with real (multi-)device placement."""
    _run("""
    import tempfile, jax, jax.numpy as jnp, numpy as np
    from repro.configs import ARCHS, reduce_config
    from repro.distributed import sharding as shd
    from repro.models import build_model
    from repro.train import checkpoint as ckpt

    cfg = reduce_config(ARCHS["qwen3-14b"])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    jax.eval_shape(model.init, jax.random.key(0))

    mesh1 = jax.make_mesh((4, 2), ("data", "model"))
    sh1 = shd.param_shardings(jax.eval_shape(lambda: params), model.axes,
                              mesh1)
    p1 = jax.device_put(params, sh1)
    d = tempfile.mkdtemp()
    ckpt.save(d, 3, p1)

    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    sh2 = shd.param_shardings(jax.eval_shape(lambda: params), model.axes,
                              mesh2)
    like = jax.eval_shape(lambda: params)
    p2, extra = ckpt.restore(d, like, shardings=sh2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("OK elastic")
    """)
