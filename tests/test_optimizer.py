"""Optimizer unit tests: AdamW dynamics, clipping, schedule, int8 gradient
compression with error feedback."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.optimizer import (OptimizerConfig, apply_updates,
                                   global_norm, init_opt_state, schedule)


def _run(cfg, steps=200, dim=8, seed=0):
    """Minimize ||Wx - y||^2 over a fixed batch; returns final loss."""
    k1, k2, k3 = jax.random.split(jax.random.key(seed), 3)
    w_true = jax.random.normal(k1, (dim, dim))
    x = jax.random.normal(k2, (32, dim))
    y = x @ w_true
    params = {"w": jax.random.normal(k3, (dim, dim)) * 0.1}
    state = init_opt_state(cfg, params)

    @jax.jit
    def step(params, state):
        def loss_fn(p):
            return jnp.mean((x @ p["w"] - y) ** 2)
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, state, _ = apply_updates(cfg, params, state, g)
        return params, state, loss

    loss = None
    for _ in range(steps):
        params, state, loss = step(params, state)
    return float(loss)


def test_adamw_converges_on_quadratic():
    cfg = OptimizerConfig(lr=3e-2, weight_decay=0.0, warmup_steps=10,
                          total_steps=200)
    assert _run(cfg) < 1e-3


def test_weight_decay_shrinks_solution():
    lo = _run(OptimizerConfig(lr=3e-2, weight_decay=0.0, total_steps=200))
    hi = _run(OptimizerConfig(lr=3e-2, weight_decay=0.5, total_steps=200))
    assert hi > lo                      # decay biases away from exact fit


def test_clipping_bounds_update():
    cfg = OptimizerConfig(lr=1.0, clip_norm=1e-3, warmup_steps=0,
                          total_steps=10, weight_decay=0.0)
    params = {"w": jnp.zeros((4,))}
    state = init_opt_state(cfg, params)
    huge = {"w": jnp.full((4,), 1e6)}
    new, _, metrics = apply_updates(cfg, params, state, huge)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)
    # post-clip grad has norm 1e-3 -> first Adam step is lr * mhat/sqrt(vhat)
    assert np.isfinite(np.asarray(new["w"])).all()
    assert np.abs(np.asarray(new["w"])).max() <= 1.5 * cfg.lr


def test_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    s = lambda t: float(schedule(cfg, jnp.asarray(t)))
    assert s(0) == pytest.approx(0.0)
    assert s(10) == pytest.approx(1.0)
    assert s(100) == pytest.approx(0.1, rel=1e-5)
    assert s(55) < s(20)


def test_compressed_grads_still_converge():
    """int8 all-reduce compression with error feedback must not break
    convergence (the error-feedback accumulator cancels quantization bias)."""
    base = OptimizerConfig(lr=3e-2, weight_decay=0.0, total_steps=300)
    comp = OptimizerConfig(lr=3e-2, weight_decay=0.0, total_steps=300,
                           compress_grads=True)
    l_base = _run(base, steps=300)
    l_comp = _run(comp, steps=300)
    assert l_comp < 50 * max(l_base, 1e-6) or l_comp < 1e-3


def test_master_weights_carry_precision():
    """bf16 params + f32 master: tiny updates must not be lost to bf16
    rounding (the classic mixed-precision failure)."""
    cfg = OptimizerConfig(lr=1e-5, weight_decay=0.0, warmup_steps=0,
                          total_steps=10_000)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = init_opt_state(cfg, params)
    g = {"w": jnp.full((4,), 1e-3, jnp.float32)}
    for _ in range(50):
        params, state, _ = apply_updates(cfg, params, state, g)
    # master moved even though each bf16 delta underflows a single step
    assert float(jnp.abs(state["master"]["w"] - 1.0).max()) > 1e-5
    assert params["w"].dtype == jnp.bfloat16


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert float(global_norm(t)) == pytest.approx(np.sqrt(3 + 16))
