"""SearchSpace invariants: encode/decode bijection, enumeration == counted
sampling support, neighborhood validity, reduction semantics."""

import random

import pytest

from repro.core.space import (Constraint, Param, SearchSpace, divisors,
                              multiples, powers_of_two)
from sweeps import random_subspace, sweep


def _toy():
    return SearchSpace(
        [Param("a", (1, 2, 4)), Param("b", (8, 16)), Param("c", (0, 1))],
        [Constraint("a_le_b", lambda c: c["a"] <= c["b"])],
        name="toy")


def test_cardinality_and_constraints():
    s = _toy()
    assert s.cardinality == 12
    assert s.constrained_cardinality() == 12      # a<=b always (4<=8)
    s2 = SearchSpace(
        [Param("a", (1, 2, 4)), Param("b", (2, 4))],
        [Constraint("a_le_b", lambda c: c["a"] <= c["b"])])
    valid = list(s2.enumerate(constrained=True))
    assert len(valid) == 5
    assert all(c["a"] <= c["b"] for c in valid)


@sweep(40)
def test_flat_index_bijection(rng):
    s = random_subspace(rng, constrained=False)
    total = s.cardinality
    idxs = rng.sample(range(total), min(total, 25))
    for i in idxs:
        cfg = s.from_flat_index(i)
        assert s.flat_index(cfg) == i
        enc = s.encode(cfg)
        assert s.decode(enc) == cfg


@sweep(40)
def test_sampling_respects_constraints(rng):
    s = random_subspace(rng)
    try:
        cfgs = s.sample_batch(20, seed=rng.randint(0, 10**6))
    except RuntimeError:
        return                       # over-constrained random space: fine
    for c in cfgs:
        assert s.satisfies(c), c


@sweep(30)
def test_neighbors_are_hamming1_and_valid(rng):
    s = random_subspace(rng)
    try:
        cfg = s.sample(random.Random(rng.randint(0, 10**6)))
    except RuntimeError:
        return
    for nb in s.neighbors(cfg):
        assert s.satisfies(nb)
        diff = [k for k in cfg if cfg[k] != nb[k]]
        assert len(diff) == 1


def test_sample_distinct_unique():
    s = _toy()
    cfgs = s.sample_distinct(12, seed=3)
    keys = {s.flat_index(c) for c in cfgs}
    assert len(keys) == len(cfgs) == 12            # full space reachable


def test_reduce_freezes_and_rewraps_constraints():
    s2 = SearchSpace(
        [Param("a", (1, 2, 4)), Param("b", (2, 4)), Param("c", (0, 1))],
        [Constraint("a_le_b", lambda c: c["a"] <= c["b"])])
    r = s2.reduce(["a"], frozen={"b": 2})
    vals = [c["a"] for c in r.enumerate()]
    assert vals == [1, 2]                          # a=4 violates vs frozen b=2


def test_duplicate_params_rejected():
    with pytest.raises(ValueError):
        SearchSpace([Param("a", (1,)), Param("a", (2,))])
    with pytest.raises(ValueError):
        Param("x", (1, 1))


def test_helpers():
    assert powers_of_two(16, 128) == (16, 32, 64, 128)
    assert divisors(12) == (1, 2, 3, 4, 6, 12)


def test_multiples_boundaries():
    # lo already on the grid: the `lo % step == 0` branch
    assert multiples(8, 16, 64) == (16, 24, 32, 40, 48, 56, 64)
    assert multiples(4, 4, 4) == (4,)
    # lo off the grid: round up to the next multiple
    assert multiples(8, 12, 64) == (16, 24, 32, 40, 48, 56, 64)
    assert multiples(5, 7, 23) == (10, 15, 20)
    assert multiples(8, 3, 30) == (8, 16, 24)
    # rounded-up start beyond hi: empty
    assert multiples(8, 12, 15) == ()
    # hi exactly on the rounded-up start
    assert multiples(8, 9, 16) == (16,)


def test_constrained_cardinality_limit_caps_count():
    s2 = SearchSpace(
        [Param("a", (1, 2, 4)), Param("b", (2, 4))],
        [Constraint("a_le_b", lambda c: c["a"] <= c["b"])])
    assert s2.constrained_cardinality() == 5
    assert s2.constrained_cardinality(limit=2) == 2
    assert s2.constrained_cardinality(limit=5) == 5
    assert s2.constrained_cardinality(limit=99) == 5


def test_bat_space_sizes():
    """Table VIII check for our BAT-TPU kernels: cardinalities are in the
    'interesting' regime (>> PolyBench's 725) and constraints bite."""
    from repro.kernels.matmul.space import GemmProblem
    from repro.kernels.conv2d.space import Conv2dProblem
    from repro.kernels.nbody.space import NbodyProblem
    from repro.kernels.pnpoly.space import PnpolyProblem

    for prob, lo in ((GemmProblem(), 1000), (Conv2dProblem(), 1000),
                     (NbodyProblem(), 500), (PnpolyProblem(), 500)):
        assert prob.space.cardinality >= lo
        # at least one constraint is active (valid < cardinality) or the
        # space is constraint-free by design
        n_valid = prob.space.constrained_cardinality(limit=50_000)
        assert 0 < n_valid <= min(prob.space.cardinality, 50_000)
