"""SearchSpace invariants: encode/decode bijection, enumeration == counted
sampling support, neighborhood validity, reduction semantics."""

import math
import random

import pytest

from repro.core.space import (Constraint, Param, SearchSpace, divisors,
                              powers_of_two)
from sweeps import random_subspace, sweep


def _toy():
    return SearchSpace(
        [Param("a", (1, 2, 4)), Param("b", (8, 16)), Param("c", (0, 1))],
        [Constraint("a_le_b", lambda c: c["a"] <= c["b"])],
        name="toy")


def test_cardinality_and_constraints():
    s = _toy()
    assert s.cardinality == 12
    assert s.constrained_cardinality() == 12      # a<=b always (4<=8)
    s2 = SearchSpace(
        [Param("a", (1, 2, 4)), Param("b", (2, 4))],
        [Constraint("a_le_b", lambda c: c["a"] <= c["b"])])
    valid = list(s2.enumerate(constrained=True))
    assert len(valid) == 5
    assert all(c["a"] <= c["b"] for c in valid)


@sweep(40)
def test_flat_index_bijection(rng):
    s = random_subspace(rng, constrained=False)
    total = s.cardinality
    idxs = rng.sample(range(total), min(total, 25))
    for i in idxs:
        cfg = s.from_flat_index(i)
        assert s.flat_index(cfg) == i
        enc = s.encode(cfg)
        assert s.decode(enc) == cfg


@sweep(40)
def test_sampling_respects_constraints(rng):
    s = random_subspace(rng)
    try:
        cfgs = s.sample_batch(20, seed=rng.randint(0, 10**6))
    except RuntimeError:
        return                       # over-constrained random space: fine
    for c in cfgs:
        assert s.satisfies(c), c


@sweep(30)
def test_neighbors_are_hamming1_and_valid(rng):
    s = random_subspace(rng)
    try:
        cfg = s.sample(random.Random(rng.randint(0, 10**6)))
    except RuntimeError:
        return
    for nb in s.neighbors(cfg):
        assert s.satisfies(nb)
        diff = [k for k in cfg if cfg[k] != nb[k]]
        assert len(diff) == 1


def test_sample_distinct_unique():
    s = _toy()
    cfgs = s.sample_distinct(12, seed=3)
    keys = {s.flat_index(c) for c in cfgs}
    assert len(keys) == len(cfgs) == 12            # full space reachable


def test_reduce_freezes_and_rewraps_constraints():
    s2 = SearchSpace(
        [Param("a", (1, 2, 4)), Param("b", (2, 4)), Param("c", (0, 1))],
        [Constraint("a_le_b", lambda c: c["a"] <= c["b"])])
    r = s2.reduce(["a"], frozen={"b": 2})
    vals = [c["a"] for c in r.enumerate()]
    assert vals == [1, 2]                          # a=4 violates vs frozen b=2


def test_duplicate_params_rejected():
    with pytest.raises(ValueError):
        SearchSpace([Param("a", (1,)), Param("a", (2,))])
    with pytest.raises(ValueError):
        Param("x", (1, 1))


def test_helpers():
    assert powers_of_two(16, 128) == (16, 32, 64, 128)
    assert divisors(12) == (1, 2, 3, 4, 6, 12)


def test_bat_space_sizes():
    """Table VIII check for our BAT-TPU kernels: cardinalities are in the
    'interesting' regime (>> PolyBench's 725) and constraints bite."""
    from repro.kernels.matmul.space import GemmProblem
    from repro.kernels.conv2d.space import Conv2dProblem
    from repro.kernels.nbody.space import NbodyProblem
    from repro.kernels.pnpoly.space import PnpolyProblem

    for prob, lo in ((GemmProblem(), 1000), (Conv2dProblem(), 1000),
                     (NbodyProblem(), 500), (PnpolyProblem(), 500)):
        assert prob.space.cardinality >= lo
        # at least one constraint is active (valid < cardinality) or the
        # space is constraint-free by design
        n_valid = prob.space.constrained_cardinality(limit=50_000)
        assert 0 < n_valid <= min(prob.space.cardinality, 50_000)
