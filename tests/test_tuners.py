"""Tuner behaviour: budget accounting, dedup, convergence, and
finds-the-optimum checks on toy landscapes (the suite's own reference
problems)."""

import random
from pathlib import Path

import pytest

from repro.core.problem import FunctionProblem
from repro.core.space import Constraint, Param, SearchSpace
from repro.core.tuners import (TUNERS, DifferentialEvolution,
                               GeneticAlgorithm, GridSearch, LocalSearch,
                               ParticleSwarm, RandomSearch,
                               SimulatedAnnealing, SurrogateBO)
from repro.core.tuners.base import run_many, run_tuner
from sweeps import sweep

ALL_TUNERS = [RandomSearch, GridSearch, LocalSearch, SimulatedAnnealing,
              GeneticAlgorithm, DifferentialEvolution, ParticleSwarm,
              SurrogateBO]


def _quad_problem(n_params=4, k=8):
    """Convex-ish separable landscape with a unique optimum at index 2."""
    params = [Param(f"p{i}", tuple(range(k))) for i in range(n_params)]
    space = SearchSpace(params, name="quad")

    def fn(cfg, arch):
        return 1.0 + sum((cfg[f"p{i}"] - 2) ** 2 for i in range(n_params))

    return FunctionProblem(space, fn, name="quad")


def _rastrigin_problem(n_params=4, k=10):
    """Multimodal: many local minima, global at index 3."""
    import math as m
    params = [Param(f"p{i}", tuple(range(k))) for i in range(n_params)]
    space = SearchSpace(params, name="rast")

    def fn(cfg, arch):
        tot = 0.0
        for i in range(n_params):
            x = (cfg[f"p{i}"] - 3) * 0.7
            tot += x * x - 3.0 * m.cos(2 * m.pi * x) + 3.0
        return 1.0 + tot

    return FunctionProblem(space, fn, name="rast")


@pytest.mark.parametrize("tuner_cls", ALL_TUNERS)
def test_budget_and_validity(tuner_cls):
    prob = _quad_problem()
    res = run_tuner(tuner_cls(prob.space, seed=0), prob, budget=40)
    assert res.evaluations <= 40
    assert all(t.valid for t in res.trials)
    curve = res.best_curve()
    assert all(b2 <= b1 + 1e-12 for b1, b2 in zip(curve, curve[1:]))


@pytest.mark.parametrize("tuner_cls", ALL_TUNERS)
def test_finds_optimum_on_small_space(tuner_cls):
    prob = _quad_problem(n_params=3, k=4)          # |S| = 64
    res = run_tuner(tuner_cls(prob.space, seed=1), prob, budget=64)
    assert res.best.objective == pytest.approx(1.0)


def test_grid_search_exhausts_and_stops():
    prob = _quad_problem(n_params=2, k=3)          # |S| = 9
    res = run_tuner(GridSearch(prob.space, seed=0), prob, budget=100)
    assert res.evaluations == 9
    keys = {prob.space.flat_index(t.config) for t in res.trials}
    assert len(keys) == 9


def test_dedup_does_not_consume_budget():
    prob = _quad_problem(n_params=1, k=4)          # tiny: forces repeats
    res = run_tuner(RandomSearch(prob.space, seed=0), prob, budget=50)
    assert res.evaluations == 4                    # only distinct configs


def test_constrained_space_never_evaluates_invalid():
    params = [Param("a", (1, 2, 3, 4)), Param("b", (1, 2, 3, 4))]
    space = SearchSpace(params, [Constraint("sum_even",
                                            lambda c: (c["a"] + c["b"]) % 2 == 0)])
    seen = []

    def fn(cfg, arch):
        seen.append(cfg)
        return float(cfg["a"] * cfg["b"])

    prob = FunctionProblem(space, fn)
    for cls in (RandomSearch, LocalSearch, GeneticAlgorithm):
        run_tuner(cls(space, seed=2), prob, budget=8)
    assert all((c["a"] + c["b"]) % 2 == 0 for c in seen)


def test_local_search_beats_random_on_smooth():
    """On a smooth landscape, hill climbing reaches the optimum with fewer
    evaluations than random search (median over seeds)."""
    prob = _quad_problem(n_params=5, k=8)           # |S| = 32768
    budget = 120

    def med_best(cls):
        runs = run_many(lambda s, seed: cls(s, seed=seed), prob, budget,
                        repeats=7)
        vals = sorted(r.best.objective for r in runs)
        return vals[len(vals) // 2]

    assert med_best(LocalSearch) <= med_best(RandomSearch)


def test_global_tuners_handle_multimodal():
    """Population/model-based tuners must not lose to random search on a
    multimodal landscape (median over seeds); GA/BO find the global basin."""
    prob = _rastrigin_problem(n_params=4, k=10)
    budget = 150

    def meds(cls):
        runs = run_many(lambda s, seed: cls(s, seed=seed), prob, budget,
                        repeats=5)
        vals = sorted(r.best.objective for r in runs)
        return vals[len(vals) // 2], min(vals)

    rnd_med, _ = meds(RandomSearch)
    for cls in (GeneticAlgorithm, SimulatedAnnealing, DifferentialEvolution,
                SurrogateBO):
        med, best = meds(cls)
        assert med <= rnd_med + 1e-9, f"{cls.__name__}: {med} vs {rnd_med}"
    for cls in (GeneticAlgorithm, SurrogateBO):
        med, best = meds(cls)
        assert best < 3.0, f"{cls.__name__}: {best}"   # global basin reached


@sweep(10)
def test_tuners_on_random_constrained_spaces(rng):
    """Any tuner on any random constrained space: returns valid trials and a
    monotone best-curve (robustness sweep)."""
    from sweeps import random_subspace
    space = random_subspace(rng, max_params=4, max_vals=5)

    def fn(cfg, arch):
        return float(sum(hash((k, v)) % 97 for k, v in cfg.items()) + 1)

    prob = FunctionProblem(space, fn)
    cls = rng.choice(ALL_TUNERS)
    try:
        res = run_tuner(cls(space, seed=rng.randint(0, 9999)), prob, budget=15)
    except RuntimeError:
        return                                   # unsatisfiable sample: fine
    assert all(t.valid for t in res.trials)
    curve = res.best_curve()
    assert all(b2 <= b1 + 1e-12 for b1, b2 in zip(curve, curve[1:]))


def test_seeds_reproducible():
    prob = _rastrigin_problem()
    r1 = run_tuner(GeneticAlgorithm(prob.space, seed=7), prob, budget=60)
    r2 = run_tuner(GeneticAlgorithm(prob.space, seed=7), prob, budget=60)
    assert [t.config for t in r1.trials] == [t.config for t in r2.trials]


# ------------------------------------------------------------------ #
# index-native engine: bit-identical to the scalar oracle
# ------------------------------------------------------------------ #
def _scalar_space(space):
    """Fresh copy of ``space`` that refuses to compile — tuners built on it
    run their legacy scalar paths (the bit-exactness oracle)."""
    s = SearchSpace(space.params, space.constraints, name=space.name)
    s.compile_eagerly = lambda *a, **k: None
    return s


def _constrained_problem():
    params = [Param("a", (1, 2, 3, 4, 5)), Param("b", (1, 2, 3, 4)),
              Param("c", (0, 1, 2))]
    space = SearchSpace(params, [
        Constraint("sum_odd", lambda c: (c["a"] + c["b"] + c["c"]) % 2 == 1,
                   vec=lambda c: (c["a"] + c["b"] + c["c"]) % 2 == 1)],
        name="constr")

    def fn(cfg, arch):
        return 1.0 + (cfg["a"] - 3) ** 2 + (cfg["b"] - 2) ** 2 + cfg["c"]

    return FunctionProblem(space, fn, name="constr")


def _traj(res):
    return [(tuple(sorted(t.config.items())), t.objective) for t in res.trials]


@pytest.mark.parametrize("tuner_cls", ALL_TUNERS)
def test_index_native_trajectory_equals_scalar_oracle(tuner_cls):
    """The tentpole property: for every tuner and seed, the index-native
    row engine walks the identical trajectory (configs AND rng draw
    stream) as the legacy scalar implementation."""
    for make in (_constrained_problem, lambda: _quad_problem(3, 5)):
        for seed in (0, 3, 11):
            prob = make()
            t_idx = tuner_cls(prob.space, seed=seed)
            assert t_idx.index_native, tuner_cls
            r_idx = run_tuner(t_idx, prob, budget=50)
            prob2 = make()
            prob2.space = _scalar_space(prob2.space)
            t_sc = tuner_cls(prob2.space, seed=seed)
            assert not t_sc.index_native
            r_sc = run_tuner(t_sc, prob2, budget=50)
            assert _traj(r_idx) == _traj(r_sc), (tuner_cls, seed)
            # and the rng streams end in the same state
            assert t_idx.rng.random() == t_sc.rng.random()


@pytest.mark.parametrize("tuner_cls", ALL_TUNERS)
def test_index_native_batched_equals_scalar_batched(tuner_cls):
    """Generational (ask_batch/tell_batch) driving: row protocol and dict
    protocol produce identical batched trajectories."""
    import math as m

    def drive(tuner, prob, budget=60):
        space = prob.space
        cache, traj, asks = {}, [], 0
        width = tuner.max_parallel_asks or 16
        while len(traj) < budget and asks < 50 * budget:
            if tuner.finished():
                break
            cfgs = tuner.ask_batch(min(width, budget - len(traj)))
            asks += len(cfgs)
            keys = [space.flat_index(c) for c in cfgs]
            fresh = [(k, c) for k, c in zip(keys, cfgs) if k not in cache]
            seen = set()
            for k, c in fresh:
                if k in seen:
                    continue
                seen.add(k)
                cache[k] = prob.evaluate(c)
                traj.append((k, cache[k].objective))
            tuner.tell_batch([cache[k] for k in keys])
        return traj

    prob = _constrained_problem()
    t_idx = tuner_cls(prob.space, seed=5)
    assert t_idx.index_native
    a = drive(t_idx, prob)
    prob2 = _constrained_problem()
    prob2.space = _scalar_space(prob2.space)
    t_sc = tuner_cls(prob2.space, seed=5)
    b = drive(t_sc, prob2)
    assert a == b, tuner_cls


def test_sample_positions_draw_identical_to_random_sample():
    """The hand-rolled ``sample_positions`` must replicate CPython's
    ``Random.sample(range(n), k)`` draw-for-draw across both algorithm
    branches (pool and rejection-set) — it feeds every tournament/donor
    selection."""
    from repro.core.tuners.base import sample_positions
    for n in list(range(1, 30)) + [40, 64, 128, 300]:
        for k in range(0, min(n, 8) + 1):
            r1, r2 = random.Random(n * 31 + k), random.Random(n * 31 + k)
            for _ in range(10):
                assert sample_positions(r1, n, k) == r2.sample(range(n), k)
                assert r1.random() == r2.random()


# ------------------------------------------------------------------ #
# surrogate-BO: batched qLCB ask + the rng-stream contract
# ------------------------------------------------------------------ #
def test_surrogate_bo_batch_width_distinct_and_prefix_stable():
    prob = _quad_problem(n_params=3, k=6)
    space = prob.space

    def warm(bo):
        rng = random.Random(99)
        for _ in range(20):
            cfg = space.sample(rng)
            bo.tell(prob.evaluate(cfg))
        assert bo.model is not None

    bo = SurrogateBO(space, seed=2, batch_width=4)
    assert bo.max_parallel_asks == 4
    warm(bo)
    batch = bo.ask_batch(4)
    keys = {space.flat_index(c) for c in batch}
    assert len(keys) == 4                  # no duplicates within a batch
    # prefix stability (the rng-stream contract): a truncated ask consumes
    # exactly the leading slots' draws
    bo2 = SurrogateBO(space, seed=2, batch_width=4)
    warm(bo2)
    batch2 = bo2.ask_batch(2)
    assert [space.flat_index(c) for c in batch2] \
        == [space.flat_index(c) for c in batch[:2]]
    # width-1 keeps the historical sequential draw sequence (no jitter)
    bo3 = SurrogateBO(space, seed=2)
    warm(bo3)
    bo4 = SurrogateBO(space, seed=2, batch_width=4)
    warm(bo4)
    assert space.flat_index(bo3.ask()) \
        == space.flat_index(bo4.ask_batch(1)[0])


def test_surrogate_bo_scalar_batch_matches_native_batch():
    prob = _constrained_problem()
    t_idx = SurrogateBO(prob.space, seed=7, n_init=8, batch_width=3)
    prob2 = _constrained_problem()
    prob2.space = _scalar_space(prob2.space)
    t_sc = SurrogateBO(prob2.space, seed=7, n_init=8, batch_width=3)
    for _ in range(12):
        a = t_idx.ask_batch(3)
        b = t_sc.ask_batch(3)
        assert [prob.space.flat_index(c) for c in a] \
            == [prob2.space.flat_index(c) for c in b]
        t_idx.tell_batch([prob.evaluate(c) for c in a])
        t_sc.tell_batch([prob2.evaluate(c) for c in b])


# --------------------------------------------------------------------- #
# warm-start seam: the pre-PR regression contract
# --------------------------------------------------------------------- #
_WARMSTART_FIXTURES = Path(__file__).parent / "fixtures" / "warmstart"


def _warmstart_manifest() -> dict:
    import json
    return json.loads((_WARMSTART_FIXTURES / "manifest.json").read_text())


@pytest.mark.parametrize("tuner_name", sorted(TUNERS))
def test_cold_journal_bit_identical_to_pre_seam_fixture(tuner_name, tmp_path):
    """Property: with ``warm_start=None`` every tuner's journaled session
    is byte-for-byte the journal recorded before the warm-start seam
    existed, and its content-addressed session id is unchanged.  Any rng
    draw, spec-identity or journal-grammar drift fails here."""
    from repro.orchestrator.runner import run_session
    from repro.orchestrator.session import SessionSpec
    from repro.orchestrator.store import SessionStore
    man = _warmstart_manifest()
    spec = SessionSpec(problem=man["problem"], tuner=tuner_name,
                       arch=man["arch"], budget=man["budget"],
                       seed=man["seed"], workers=man["workers"])
    assert spec.session_id == man["session_ids"][tuner_name], \
        "spec identity drifted: pre-PR session ids must be stable"
    store = SessionStore(tmp_path, clock=lambda: 0.0)
    store.create(spec)
    run_session(spec, store=store)
    got = (tmp_path / spec.session_id / "trials.jsonl").read_bytes()
    want = (_WARMSTART_FIXTURES / f"{tuner_name}.trials.jsonl").read_bytes()
    assert got == want, "cold trajectory diverged from the pre-seam journal"


@pytest.mark.parametrize("tuner_name", sorted(TUNERS))
def test_warm_started_run_satisfies_stepper_contract(tuner_name, tmp_path):
    """Property: warm-started sessions honor the stepper/rng contract —
    interrupting at an arbitrary batch boundary and resuming replays the
    exact uninterrupted trajectory, warm queue included."""
    from repro.orchestrator.runner import (resume_session, run_session)
    from repro.orchestrator.session import SessionSpec
    from repro.orchestrator.store import SessionStore
    space4 = SearchSpace([Param(f"p{i}", tuple(range(8))) for i in range(4)])
    opt = space4.flat_index({f"p{i}": 2 for i in range(4)})
    spec = SessionSpec(problem="toy_quad", tuner=tuner_name, arch="v5e",
                       budget=24, seed=11, workers=2,
                       warm_start=[opt + 3, opt, opt + 16])
    s_full = SessionStore(tmp_path / "full", clock=lambda: 0.0)
    s_full.create(spec)
    full = run_session(spec, store=s_full)
    # the warm rows lead the trace in queue order
    assert [space4.flat_index(t.config) for t in full.trials[:3]] \
        == spec.warm_start
    s_cut = SessionStore(tmp_path / "cut", clock=lambda: 0.0)
    s_cut.create(spec)
    run_session(spec, store=s_cut, stop_after=5)
    resumed = resume_session(spec.session_id, s_cut)
    assert [t.config for t in resumed.trials] \
        == [t.config for t in full.trials]
    assert (tmp_path / "cut" / spec.session_id / "trials.jsonl").read_bytes() \
        == (tmp_path / "full" / spec.session_id / "trials.jsonl").read_bytes()
