"""Tuner behaviour: budget accounting, dedup, convergence, and
finds-the-optimum checks on toy landscapes (the suite's own reference
problems)."""

import math
import random

import pytest

from repro.core.problem import FunctionProblem
from repro.core.space import Constraint, Param, SearchSpace
from repro.core.tuners import (DifferentialEvolution, GeneticAlgorithm,
                               GridSearch, LocalSearch, ParticleSwarm,
                               RandomSearch, SimulatedAnnealing, SurrogateBO)
from repro.core.tuners.base import run_many, run_tuner
from sweeps import sweep

ALL_TUNERS = [RandomSearch, GridSearch, LocalSearch, SimulatedAnnealing,
              GeneticAlgorithm, DifferentialEvolution, ParticleSwarm,
              SurrogateBO]


def _quad_problem(n_params=4, k=8):
    """Convex-ish separable landscape with a unique optimum at index 2."""
    params = [Param(f"p{i}", tuple(range(k))) for i in range(n_params)]
    space = SearchSpace(params, name="quad")

    def fn(cfg, arch):
        return 1.0 + sum((cfg[f"p{i}"] - 2) ** 2 for i in range(n_params))

    return FunctionProblem(space, fn, name="quad")


def _rastrigin_problem(n_params=4, k=10):
    """Multimodal: many local minima, global at index 3."""
    import math as m
    params = [Param(f"p{i}", tuple(range(k))) for i in range(n_params)]
    space = SearchSpace(params, name="rast")

    def fn(cfg, arch):
        tot = 0.0
        for i in range(n_params):
            x = (cfg[f"p{i}"] - 3) * 0.7
            tot += x * x - 3.0 * m.cos(2 * m.pi * x) + 3.0
        return 1.0 + tot

    return FunctionProblem(space, fn, name="rast")


@pytest.mark.parametrize("tuner_cls", ALL_TUNERS)
def test_budget_and_validity(tuner_cls):
    prob = _quad_problem()
    res = run_tuner(tuner_cls(prob.space, seed=0), prob, budget=40)
    assert res.evaluations <= 40
    assert all(t.valid for t in res.trials)
    curve = res.best_curve()
    assert all(b2 <= b1 + 1e-12 for b1, b2 in zip(curve, curve[1:]))


@pytest.mark.parametrize("tuner_cls", ALL_TUNERS)
def test_finds_optimum_on_small_space(tuner_cls):
    prob = _quad_problem(n_params=3, k=4)          # |S| = 64
    res = run_tuner(tuner_cls(prob.space, seed=1), prob, budget=64)
    assert res.best.objective == pytest.approx(1.0)


def test_grid_search_exhausts_and_stops():
    prob = _quad_problem(n_params=2, k=3)          # |S| = 9
    res = run_tuner(GridSearch(prob.space, seed=0), prob, budget=100)
    assert res.evaluations == 9
    keys = {prob.space.flat_index(t.config) for t in res.trials}
    assert len(keys) == 9


def test_dedup_does_not_consume_budget():
    prob = _quad_problem(n_params=1, k=4)          # tiny: forces repeats
    res = run_tuner(RandomSearch(prob.space, seed=0), prob, budget=50)
    assert res.evaluations == 4                    # only distinct configs


def test_constrained_space_never_evaluates_invalid():
    params = [Param("a", (1, 2, 3, 4)), Param("b", (1, 2, 3, 4))]
    space = SearchSpace(params, [Constraint("sum_even",
                                            lambda c: (c["a"] + c["b"]) % 2 == 0)])
    seen = []

    def fn(cfg, arch):
        seen.append(cfg)
        return float(cfg["a"] * cfg["b"])

    prob = FunctionProblem(space, fn)
    for cls in (RandomSearch, LocalSearch, GeneticAlgorithm):
        run_tuner(cls(space, seed=2), prob, budget=8)
    assert all((c["a"] + c["b"]) % 2 == 0 for c in seen)


def test_local_search_beats_random_on_smooth():
    """On a smooth landscape, hill climbing reaches the optimum with fewer
    evaluations than random search (median over seeds)."""
    prob = _quad_problem(n_params=5, k=8)           # |S| = 32768
    budget = 120

    def med_best(cls):
        runs = run_many(lambda s, seed: cls(s, seed=seed), prob, budget,
                        repeats=7)
        vals = sorted(r.best.objective for r in runs)
        return vals[len(vals) // 2]

    assert med_best(LocalSearch) <= med_best(RandomSearch)


def test_global_tuners_handle_multimodal():
    """Population/model-based tuners must not lose to random search on a
    multimodal landscape (median over seeds); GA/BO find the global basin."""
    prob = _rastrigin_problem(n_params=4, k=10)
    budget = 150

    def meds(cls):
        runs = run_many(lambda s, seed: cls(s, seed=seed), prob, budget,
                        repeats=5)
        vals = sorted(r.best.objective for r in runs)
        return vals[len(vals) // 2], min(vals)

    rnd_med, _ = meds(RandomSearch)
    for cls in (GeneticAlgorithm, SimulatedAnnealing, DifferentialEvolution,
                SurrogateBO):
        med, best = meds(cls)
        assert med <= rnd_med + 1e-9, f"{cls.__name__}: {med} vs {rnd_med}"
    for cls in (GeneticAlgorithm, SurrogateBO):
        med, best = meds(cls)
        assert best < 3.0, f"{cls.__name__}: {best}"   # global basin reached


@sweep(10)
def test_tuners_on_random_constrained_spaces(rng):
    """Any tuner on any random constrained space: returns valid trials and a
    monotone best-curve (robustness sweep)."""
    from sweeps import random_subspace
    space = random_subspace(rng, max_params=4, max_vals=5)

    def fn(cfg, arch):
        return float(sum(hash((k, v)) % 97 for k, v in cfg.items()) + 1)

    prob = FunctionProblem(space, fn)
    cls = rng.choice(ALL_TUNERS)
    try:
        res = run_tuner(cls(space, seed=rng.randint(0, 9999)), prob, budget=15)
    except RuntimeError:
        return                                   # unsatisfiable sample: fine
    assert all(t.valid for t in res.trials)
    curve = res.best_curve()
    assert all(b2 <= b1 + 1e-12 for b1, b2 in zip(curve, curve[1:]))


def test_seeds_reproducible():
    prob = _rastrigin_problem()
    r1 = run_tuner(GeneticAlgorithm(prob.space, seed=7), prob, budget=60)
    r2 = run_tuner(GeneticAlgorithm(prob.space, seed=7), prob, budget=60)
    assert [t.config for t in r1.trials] == [t.config for t in r2.trials]
