"""Conformance for the shared retry policy (:mod:`repro.core.retry`):
deterministic jitter, cap/bound arithmetic, budget edge cases, and the
wrap-vs-propagate contract of :func:`retry_call`.  The staticcheck
``retry-sleep`` rule forbids hand-rolled backoff elsewhere precisely
because this is the one tested copy.
"""

from __future__ import annotations

import pytest

from repro.core.retry import RetryBudgetExceeded, backoff_delays, retry_call


# --------------------------------------------------------------------- #
# backoff_delays
# --------------------------------------------------------------------- #

def test_delays_deterministic_per_salt():
    a = list(backoff_delays(6, salt="broker"))
    b = list(backoff_delays(6, salt="broker"))
    c = list(backoff_delays(6, salt="publish"))
    assert a == b                      # same salt: bit-identical schedule
    assert a != c                      # different salt: decorrelated


def test_delays_bounded_by_cap_and_jitter_window():
    base, cap, jit = 0.01, 0.2, 0.5
    delays = list(backoff_delays(12, base_s=base, max_s=cap, jitter=jit,
                                 salt="x"))
    assert len(delays) == 12
    raw = base
    for d in delays:
        ceil = min(raw, cap)
        assert ceil * (1 - jit) <= d <= ceil   # jitter only shrinks
        raw = min(raw * 2, cap)
    # the tail is capped: every late delay fits under the cap
    assert all(d <= cap for d in delays)


def test_zero_jitter_is_plain_capped_doubling():
    delays = list(backoff_delays(5, base_s=0.01, max_s=0.05, jitter=0.0))
    assert delays == [0.01, 0.02, 0.04, 0.05, 0.05]


def test_zero_retries_yields_nothing():
    assert list(backoff_delays(0)) == []


@pytest.mark.parametrize("bad", [-0.1, 1.5])
def test_jitter_out_of_range_rejected(bad):
    with pytest.raises(ValueError, match="jitter"):
        list(backoff_delays(3, jitter=bad))


# --------------------------------------------------------------------- #
# retry_call
# --------------------------------------------------------------------- #

class Flaky:
    """Fails ``failures`` times, then returns ``value``."""

    def __init__(self, failures, exc=OSError("busy"), value="ok"):
        self.failures = failures
        self.exc = exc
        self.value = value
        self.calls = 0

    def __call__(self):
        self.calls += 1
        if self.calls <= self.failures:
            raise self.exc
        return self.value


def test_succeeds_after_transient_failures():
    fn = Flaky(2)
    slept = []
    out = retry_call(fn, retries=3, retry_on=lambda e: True,
                     sleep=slept.append)
    assert out == "ok" and fn.calls == 3
    assert slept == list(backoff_delays(3))[:2]   # one sleep per failure


def test_zero_retries_runs_once_and_propagates():
    fn = Flaky(1)
    slept = []
    with pytest.raises(OSError):
        retry_call(fn, retries=0, retry_on=lambda e: True,
                   sleep=slept.append)
    assert fn.calls == 1 and slept == []          # never slept, never retried


def test_non_transient_propagates_immediately():
    fn = Flaky(5, exc=KeyError("fatal"))
    with pytest.raises(KeyError):
        retry_call(fn, retries=5, retry_on=lambda e: isinstance(e, OSError),
                   sleep=lambda s: None)
    assert fn.calls == 1


def test_exhausted_budget_raises_last_exception():
    fn = Flaky(10)
    with pytest.raises(OSError, match="busy"):
        retry_call(fn, retries=2, retry_on=lambda e: True,
                   sleep=lambda s: None)
    assert fn.calls == 3                          # retries + 1 attempts


def test_exhausted_budget_wraps_when_named():
    fn = Flaky(10)
    with pytest.raises(RetryBudgetExceeded) as ei:
        retry_call(fn, retries=2, retry_on=lambda e: True,
                   sleep=lambda s: None, what="broker.submit")
    assert ei.value.attempts == 3
    assert isinstance(ei.value.last, OSError)
    assert "broker.submit" in str(ei.value)


def test_non_transient_never_wrapped_even_with_what():
    fn = Flaky(1, exc=KeyError("fatal"))
    with pytest.raises(KeyError):
        retry_call(fn, retries=2, retry_on=lambda e: isinstance(e, OSError),
                   sleep=lambda s: None, what="broker.submit")


def test_sleep_receives_the_salted_schedule():
    fn = Flaky(3)
    slept = []
    retry_call(fn, retries=3, retry_on=lambda e: True, salt="site-a",
               sleep=slept.append)
    assert slept == list(backoff_delays(3, salt="site-a"))
