"""CompiledSpace engine: every compiled path must agree *exactly* with the
legacy iterator path — same configs, same orders, same rng draw sequences,
same FFG arrays.  The legacy implementations stay in the tree as the
reference oracles (``SearchSpace.enumerate``/``neighbors``/rejection
``sample``, ``build_ffg_reference``)."""

import math
import random

import numpy as np
import pytest

from repro.core.analysis.centrality import (build_ffg, build_ffg_reference,
                                            pagerank)
from repro.core.costmodel import (ARCH_NAMES, FeatureBatch, KernelFeatures,
                                  estimate_seconds, estimate_seconds_batch)
from repro.core.problem import FunctionProblem
from repro.core.results import ResultTable
from repro.core.space import Constraint, Param, SearchSpace
from repro.core.spacetable import CompiledSpace, space_fingerprint
from sweeps import random_subspace, sweep


def _fresh(space):
    """Uncompiled copy of a space: the legacy reference instance."""
    return SearchSpace(space.params, space.constraints, name=space.name)


# ------------------------------------------------------------------ #
# enumeration / counting
# ------------------------------------------------------------------ #
@sweep(40)
def test_valid_configs_match_iterator(rng):
    s = random_subspace(rng)
    legacy = list(_fresh(s).enumerate(constrained=True))
    assert s.valid_configs() == legacy
    comp = s.compiled()
    assert comp.n_valid == len(legacy)
    assert [tuple(r) for r in
            CompiledSpace.codes_for(s, comp.valid_rows).tolist()] \
        == [_fresh(s).encode(c) for c in legacy]


@sweep(25)
def test_constrained_cardinality_limit_semantics(rng):
    s = random_subspace(rng)
    n = len(list(_fresh(s).enumerate(constrained=True)))
    assert s.constrained_cardinality() == n
    for limit in (0, 1, max(0, n - 1), n, n + 5):
        expect = min(n, limit)
        assert s.constrained_cardinality(limit=limit) == expect


def test_constrained_cardinality_legacy_branch(monkeypatch):
    """With compilation disabled the iterator count must agree."""
    monkeypatch.setattr("repro.core.spacetable.DEFAULT_COMPILE_LIMIT", 0)
    s = SearchSpace(
        [Param("a", (1, 2, 3, 4)), Param("b", (1, 2))],
        [Constraint("even", lambda c: (c["a"] + c["b"]) % 2 == 0)])
    assert s.compiled() is None
    assert s.constrained_cardinality() == 4
    assert s.constrained_cardinality(limit=3) == 3


# ------------------------------------------------------------------ #
# sampling: identical draw sequences
# ------------------------------------------------------------------ #
@sweep(30)
def test_sample_sequence_identical_to_legacy(rng):
    s = random_subspace(rng)
    s.compiled()
    seed = rng.randint(0, 10 ** 6)
    try:
        compiled_draws = [s.sample(random.Random(seed)) for _ in range(1)]
        compiled_seq = []
        r = random.Random(seed)
        for _ in range(25):
            compiled_seq.append(s.sample(r))
    except RuntimeError:
        return                        # over-constrained random space: fine
    legacy = _fresh(s)
    r = random.Random(seed)
    legacy_seq = [legacy.sample(r) for _ in range(25)]
    assert compiled_seq == legacy_seq
    assert compiled_draws[0] == legacy_seq[0]


@sweep(20)
def test_sample_distinct_identical_to_legacy(rng):
    s = random_subspace(rng)
    s.compiled()
    seed = rng.randint(0, 10 ** 6)
    try:
        got = s.sample_distinct(10, seed=seed)
    except RuntimeError:
        return
    assert got == _fresh(s).sample_distinct(10, seed=seed)


def test_rejection_free_sampling_uniform_support():
    s = SearchSpace(
        [Param("a", (1, 2, 3, 4)), Param("b", (1, 2))],
        [Constraint("even", lambda c: (c["a"] + c["b"]) % 2 == 0)])
    comp = s.compiled()
    rng = random.Random(0)
    seen = {comp.sample_row(rng) for _ in range(400)}
    assert seen == set(comp.valid_rows.tolist())     # all 4 valid reachable
    for _ in range(50):
        assert s.satisfies(comp.sample(rng))
    rows = comp.sample_rows_distinct(10, random.Random(1))
    assert len(set(rows.tolist())) == len(rows) == comp.n_valid


# ------------------------------------------------------------------ #
# neighbors: CSR table vs iterator
# ------------------------------------------------------------------ #
@sweep(30)
def test_neighbors_list_matches_iterator(rng):
    s = random_subspace(rng)
    legacy = _fresh(s)
    try:
        cfgs = s.sample_distinct(8, seed=rng.randint(0, 10 ** 6))
    except RuntimeError:
        return
    s.compiled()
    for cfg in cfgs:
        assert s.neighbors_list(cfg) == list(legacy.neighbors(cfg))


def test_neighbors_list_invalid_config_falls_back():
    s = SearchSpace(
        [Param("a", (1, 2, 3, 4)), Param("b", (1, 2))],
        [Constraint("even", lambda c: (c["a"] + c["b"]) % 2 == 0)])
    s.compiled()
    bad = {"a": 1, "b": 2}            # violates the constraint
    assert not s.satisfies(bad)
    assert s.neighbors_list(bad) == list(_fresh(s).neighbors(bad))


def test_csr_structure():
    s = SearchSpace([Param("a", (0, 1, 2)), Param("b", (0, 1))])
    comp = s.compiled()
    indptr, indices = comp.csr_neighbors()
    assert len(indptr) == comp.n_valid + 1
    assert indptr[-1] == len(indices)
    # unconstrained: every node has (3-1) + (2-1) = 3 Hamming-1 neighbors
    assert np.all(np.diff(indptr) == 3)


# ------------------------------------------------------------------ #
# batched encode / flat index
# ------------------------------------------------------------------ #
@sweep(25)
def test_batched_encode_flat_roundtrip(rng):
    s = random_subspace(rng, constrained=False)
    cfgs = [s.from_flat_index(i)
            for i in rng.sample(range(s.cardinality),
                                min(s.cardinality, 30))]
    enc = s.encode_many(cfgs)
    assert [tuple(r) for r in enc.tolist()] == [s.encode(c) for c in cfgs]
    flat = s.flat_index_many(cfgs)
    assert flat.tolist() == [s.flat_index(c) for c in cfgs]
    comp = s.compiled()
    assert comp.decode_many(flat) == cfgs
    assert [comp.decode_row(int(i)) for i in flat] == cfgs


# ------------------------------------------------------------------ #
# FFG: vectorized join vs reference double loop
# ------------------------------------------------------------------ #
def _assert_ffg_equal(a, b):
    assert a.n == b.n
    assert np.array_equal(a.fitness, b.fitness)
    assert np.array_equal(a.src, b.src)
    assert np.array_equal(a.dst, b.dst)
    assert np.array_equal(a.minima, b.minima)


@sweep(25)
def test_build_ffg_matches_reference_exhaustive(rng):
    s = random_subspace(rng)
    prob = FunctionProblem(
        s, lambda c, a: float(sum(v * (i + 1)
                                  for i, v in enumerate(c.values())) % 17))
    trials = prob.exhaustive("v5e")
    if not trials:
        return
    table = ResultTable.from_trials(prob, "v5e", trials, "exhaustive")
    _assert_ffg_equal(build_ffg(s, table), build_ffg_reference(s, table))


@sweep(15)
def test_build_ffg_searchsorted_path_without_compiled_space(rng):
    """With compilation disabled, the sort/searchsorted join (not the CSR
    shortcut) must still reproduce the reference exactly."""
    s = random_subspace(rng)
    prob = FunctionProblem(
        s, lambda c, a: float(sum(v for v in c.values()) % 11))
    import repro.core.spacetable as st
    saved = st.DEFAULT_COMPILE_LIMIT
    st.DEFAULT_COMPILE_LIMIT = 0
    try:
        trials = prob.exhaustive("v5e")
        if not trials:
            return
        table = ResultTable.from_trials(prob, "v5e", trials, "exhaustive")
        assert s.compiled(build=False) is None
        _assert_ffg_equal(build_ffg(s, table), build_ffg_reference(s, table))
    finally:
        st.DEFAULT_COMPILE_LIMIT = saved


@sweep(15)
def test_build_ffg_matches_reference_sampled_with_dups_and_inf(rng):
    """Sampled tables: duplicates (first occurrence wins) and inf rows
    (dropped) must behave identically on both paths."""
    s = random_subspace(rng, constrained=False)
    cfgs = [s.from_flat_index(rng.randrange(s.cardinality))
            for _ in range(40)]
    objectives = [math.inf if rng.random() < 0.15
                  else float(rng.randint(0, 9)) for _ in cfgs]
    table = ResultTable(
        problem="toy", arch="v5e", param_names=s.param_names,
        configs=[s.encode(c) for c in cfgs], objectives=objectives,
        protocol="sampled")
    _assert_ffg_equal(build_ffg(s, table), build_ffg_reference(s, table))


def test_build_ffg_empty_table():
    s = SearchSpace([Param("a", (0, 1))])
    table = ResultTable(problem="t", arch="v5e", param_names=("a",),
                        configs=[], objectives=[], protocol="x")
    ffg = build_ffg(s, table)
    assert ffg.n == 0 and len(ffg.src) == 0
    assert len(pagerank(ffg)) == 0


def test_pagerank_no_edges():
    s = SearchSpace([Param("a", (0, 1, 2))])
    table = ResultTable(problem="t", arch="v5e", param_names=("a",),
                        configs=[(0,), (1,), (2,)],
                        objectives=[1.0, 1.0, 1.0], protocol="x")
    ffg = build_ffg(s, table)             # flat landscape: all dangling
    pr = pagerank(ffg)
    assert pr == pytest.approx([1 / 3] * 3)


# ------------------------------------------------------------------ #
# vectorized-constraint protocol
# ------------------------------------------------------------------ #
def _vec_space():
    return SearchSpace(
        [Param("x", (1, 2, 3, 4, 5)), Param("y", (2, 4, 6)),
         Param("mode", ("lo", "hi"))],
        [Constraint("x_le_y", lambda c: c["x"] <= c["y"],
                    vec=lambda c: c["x"] <= c["y"]),
         Constraint("hi_even", lambda c: c["mode"] == "lo"
                    or c["x"] % 2 == 0,
                    vec=lambda c: (c["mode"] == "lo") | (c["x"] % 2 == 0))],
        name="vecdemo")


def test_vectorized_constraints_match_python_predicates():
    s = _vec_space()
    legacy = SearchSpace(
        s.params, [Constraint(c.name, c.fn) for c in s.constraints],
        name=s.name)
    assert s.valid_configs() == list(legacy.enumerate(constrained=True))


def test_vectorized_constraint_bad_shape_rejected():
    s = SearchSpace([Param("x", (1, 2, 3))],
                    [Constraint("bad", lambda c: True,
                                vec=lambda c: np.array([True]))])
    with pytest.raises(ValueError, match="vec returned shape"):
        CompiledSpace.build(s)


def test_reduce_wraps_vectorized_constraints():
    s = _vec_space()
    r = s.reduce(["x"], frozen={"y": 4, "mode": "hi"})
    assert r.constraints[0].vec is not None
    legacy = [c["x"] for c in r.enumerate(constrained=True)]
    comp = r.compiled()
    assert [c["x"] for c in comp.valid_configs()] == legacy == [2, 4]


# ------------------------------------------------------------------ #
# mixed short-circuit ordering (python predicate guarded by earlier one)
# ------------------------------------------------------------------ #
def test_python_fallback_preserves_declaration_order():
    """A python predicate that would raise on rows an earlier constraint
    rejects must never see those rows (legacy ``satisfies`` short-circuit)."""
    s = SearchSpace(
        [Param("a", (0, 1, 2)), Param("b", (1, 2))],
        [Constraint("a_pos", lambda c: c["a"] > 0),
         Constraint("div", lambda c: c["b"] % c["a"] == 0)])
    legacy = list(_fresh(s).enumerate(constrained=True))
    assert s.valid_configs() == legacy


# ------------------------------------------------------------------ #
# on-disk exhaustive-table cache
# ------------------------------------------------------------------ #
def test_cache_roundtrip(tmp_path):
    s = _vec_space()
    comp = CompiledSpace.build(s, cache_dir=tmp_path)
    # lazy CSR build re-persists into the same cache entry automatically
    indptr, indices = comp.csr_neighbors()

    loaded = CompiledSpace.build(_fresh(s), cache_dir=tmp_path)
    assert np.array_equal(loaded.mask, comp.mask)
    assert loaded._nbr_indptr is not None     # CSR came from disk, not lazy
    lp, li = loaded.csr_neighbors()
    assert np.array_equal(lp, indptr) and np.array_equal(li, indices)


def test_cache_fingerprint_mismatch_rebuilds(tmp_path):
    s = _vec_space()
    CompiledSpace.build(s, cache_dir=tmp_path)
    changed = SearchSpace(
        [Param("x", (1, 2, 3, 4, 5)), Param("y", (2, 4, 6)),
         Param("mode", ("lo", "hi", "xx"))],
        s.constraints, name=s.name)    # same name, different values
    comp = CompiledSpace.build(changed, cache_dir=tmp_path)
    assert comp.n_total == changed.cardinality


def test_cache_corrupt_file_rebuilds(tmp_path):
    s = _vec_space()
    path = tmp_path / f"{s.name}-{space_fingerprint(s)}.npz"
    path.write_bytes(b"not an npz")
    comp = CompiledSpace.build(s, cache_dir=tmp_path)
    assert comp.n_valid == len(list(_fresh(s).enumerate(constrained=True)))


# ------------------------------------------------------------------ #
# pickling (process worker pools): derived state must not cross
# ------------------------------------------------------------------ #
def test_space_pickles_without_compiled_state():
    import pickle
    s = SearchSpace([Param("a", (1, 2, 3))], name="picklable")
    s.compiled()
    s2 = pickle.loads(pickle.dumps(s))
    assert s2._compiled is None
    assert s2.valid_configs() == s.valid_configs()


# ------------------------------------------------------------------ #
# FeatureBatch struct-of-arrays cost-model path
# ------------------------------------------------------------------ #
def test_feature_batch_columns_match_scalar():
    rng = random.Random(3)
    feats = [KernelFeatures(
        mxu_flops=rng.uniform(1e9, 1e12), vpu_flops=rng.uniform(0, 1e10),
        hbm_bytes=rng.uniform(1e3, 1e9),
        vmem_working_set=rng.uniform(0, 2e8),
        grid_steps=rng.uniform(1, 1e4),
        mxu_tile=(rng.choice([8, 128]), rng.choice([8, 512]), 256),
        dtype_bytes=rng.choice([2, 4]), lane_extent=rng.choice([100, 257]),
        sublane_extent=8, unroll=rng.choice([1, 8]),
        inner_trip=rng.choice([0, 4]),
    ) for _ in range(50)]
    batch = FeatureBatch.from_features(feats)
    assert len(batch) == 50 and len(batch.features) == 50
    for arch in ARCH_NAMES:
        out = estimate_seconds_batch(batch, arch)
        for f, v in zip(feats, out):
            s = estimate_seconds(f, arch)
            assert (math.isinf(s) and math.isinf(v)) or s == float(v)


def test_feature_batch_native_columns():
    """A problem building columns directly (no per-row KernelFeatures)."""
    n = 16
    cols = dict(
        vmem_working_set=np.zeros(n), dtype_bytes=np.full(n, 4.0),
        mxu_flops=np.zeros(n), vpu_flops=np.full(n, 1e9),
        transcendental_ops=np.zeros(n), hbm_bytes=np.full(n, 1e6),
        gather_bytes=np.zeros(n), grid_steps=np.ones(n),
        serialization=np.zeros(n), extra_seconds=np.zeros(n),
        tile_m=np.full(n, 128.0), tile_n=np.full(n, 128.0),
        tile_k=np.full(n, 128.0), lane_extent=np.full(n, 128.0),
        sublane_extent=np.full(n, 8.0), unroll=np.ones(n),
        inner_trip=np.ones(n))
    batch = FeatureBatch(**cols)
    assert batch.features == ()
    ref = estimate_seconds(KernelFeatures(
        vpu_flops=1e9, hbm_bytes=1e6, dtype_bytes=4, lane_extent=128,
        sublane_extent=8), "v5e")
    out = estimate_seconds_batch(batch, "v5e")
    assert out == pytest.approx([ref] * n)

    with pytest.raises(ValueError, match="length"):
        FeatureBatch(**{**cols, "unroll": np.ones(n + 1)})


# ------------------------------------------------------------------ #
# row-native draws: identical rng sequences to the legacy dict paths
# ------------------------------------------------------------------ #
@sweep(30)
def test_sample_row_rejection_matches_legacy_sample(rng):
    s = random_subspace(rng)
    comp = s.compiled()
    legacy = _fresh(s)
    seed = rng.randint(0, 10 ** 6)
    r1, r2 = random.Random(seed), random.Random(seed)
    try:
        for _ in range(20):
            row = comp.sample_row_rejection(r1)
            assert row == legacy.flat_index(legacy.sample(r2))
        assert r1.random() == r2.random()     # streams stay in lockstep
    except RuntimeError:
        return                                # over-constrained: fine


@sweep(30)
def test_random_neighbor_row_matches_legacy(rng):
    s = random_subspace(rng)
    comp = s.compiled()
    legacy = _fresh(s)
    seed = rng.randint(0, 10 ** 6)
    try:
        row = comp.sample_row_rejection(random.Random(seed))
    except RuntimeError:
        return
    cfg = legacy.from_flat_index(row)
    r1, r2 = random.Random(seed + 1), random.Random(seed + 1)
    for _ in range(40):
        nrow = comp.random_neighbor_row(row, r1)
        ncfg = legacy.random_neighbor(cfg, r2)
        assert nrow == legacy.flat_index(ncfg)
        row, cfg = nrow, ncfg
    assert r1.random() == r2.random()


@sweep(25)
def test_edge_params_identify_moved_parameter(rng):
    s = random_subspace(rng)
    comp = s.compiled()
    indptr, indices = comp.csr_neighbors()
    ep = comp.edge_params()
    assert len(ep) == len(indices)
    if not len(indices):
        return
    src_pos = np.repeat(np.arange(comp.n_valid), np.diff(indptr))
    sc = CompiledSpace.codes_for(s, comp.valid_rows[src_pos])
    dc = CompiledSpace.codes_for(s, comp.valid_rows[indices])
    diff = sc != dc
    assert np.all(diff.sum(axis=1) == 1)      # Hamming-1 by construction
    assert np.array_equal(ep, np.argmax(diff, axis=1))


def test_value_columns_match_decode():
    s = SearchSpace([Param("a", (4, 8, 16)), Param("b", ("x", "y")),
                     Param("c", (1.5, 2.5))],
                    [Constraint("no_8y", lambda c: not (c["a"] == 8
                                                        and c["b"] == "y"))])
    comp = s.compiled()
    rows = comp.valid_rows
    cols = comp.value_columns(rows)
    cfgs = comp.decode_many(rows)
    for name in s.param_names:
        assert cols[name].tolist() == [c[name] for c in cfgs]


# ------------------------------------------------------------------ #
# alias-sampled neighbor moves
# ------------------------------------------------------------------ #
def test_alias_distribution_matches_rejection():
    """The alias sampler must draw from the same conditional distribution
    as the legacy rejection scheme: each valid neighbor weighted by one
    over the moved parameter's cardinality (NOT uniform over neighbors —
    the cardinalities here differ on purpose)."""
    s = SearchSpace([Param("a", tuple(range(6))), Param("b", (0, 1)),
                     Param("c", tuple(range(4)))],
                    [Constraint("skip", lambda c: (c["a"] + c["b"]
                                                   + c["c"]) % 7 != 0)])
    comp = s.compiled()
    row = int(comp.valid_rows[5])
    n = 60_000
    rng = random.Random(0)
    alias_counts: dict[int, int] = {}
    for _ in range(n):
        k = comp.sample_neighbor_alias(row, rng)
        alias_counts[k] = alias_counts.get(k, 0) + 1
    rng = random.Random(1)
    rej_counts: dict[int, int] = {}
    for _ in range(n):
        k = comp.random_neighbor_row(row, rng)
        rej_counts[k] = rej_counts.get(k, 0) + 1
    assert sorted(alias_counts) == sorted(rej_counts)
    assert len(alias_counts) > 1
    for k in alias_counts:
        fa, fr = alias_counts[k] / n, rej_counts[k] / n
        assert abs(fa - fr) < 0.01, (k, fa, fr)
    # and the exact expected weights: 1/card(moved param), normalized
    nbrs = comp.neighbor_rows(row)
    ep = comp.edge_params()
    indptr, _ = comp.csr_neighbors()
    pos = int(comp.row_pos[row])
    w = 1.0 / comp.cards[ep[indptr[pos]:indptr[pos + 1]]]
    w = w / w.sum()
    for nb, expect in zip(nbrs.tolist(), w):
        assert abs(alias_counts[nb] / n - expect) < 0.01


def test_alias_degenerate_row_and_invalid_row():
    """A valid row with no valid neighbors yields -1 (no draws wasted on
    the 1000-try rejection loop); rows outside the valid set are
    rejected."""
    s = SearchSpace([Param("a", (0, 1, 2)), Param("b", (0, 1, 2))],
                    [Constraint("diag", lambda c: c["a"] == c["b"])])
    comp = s.compiled()
    assert comp.n_valid == 3
    indptr, indices = comp.csr_neighbors()
    assert len(indices) == 0                  # every valid row is isolated
    rng = random.Random(0)
    for row in comp.valid_rows:
        assert comp.sample_neighbor_alias(int(row), rng) == -1
    bad = int(np.flatnonzero(~comp.mask)[0])
    with pytest.raises(ValueError):
        comp.sample_neighbor_alias(bad, rng)
    # rejection path on a degenerate row: exhausts tries, stays put
    row0 = int(comp.valid_rows[0])
    assert comp.random_neighbor_row(row0, rng, max_tries=50) == row0


def test_annealing_alias_mode_walks_valid_rows():
    """Opt-in alias moves: seeded-reproducible, every proposal valid, and
    degenerate rows propose the current config again (staying put) rather
    than burning the rejection try budget."""
    from repro.core.problem import FunctionProblem
    from repro.core.tuners import SimulatedAnnealing
    from repro.core.tuners.base import run_tuner

    s = SearchSpace([Param("a", tuple(range(5))), Param("b", tuple(range(5)))],
                    [Constraint("sum", lambda c: (c["a"] + c["b"]) % 3 != 0)])
    prob = FunctionProblem(s, lambda c, arch: 1.0 + c["a"] * 5 + c["b"])
    r1 = run_tuner(SimulatedAnnealing(s, seed=4, moves="alias"),
                   prob, budget=40)
    s2 = SearchSpace(s.params, s.constraints, name=s.name)
    r2 = run_tuner(SimulatedAnnealing(s2, seed=4, moves="alias"),
                   prob, budget=40)
    assert [t.config for t in r1.trials] == [t.config for t in r2.trials]
    assert all(s.satisfies(t.config) for t in r1.trials)
    with pytest.raises(ValueError):
        SimulatedAnnealing(s, seed=0, moves="nope")
