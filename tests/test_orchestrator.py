"""Orchestrator subsystem: parallel == serial equivalence, exact resume
without re-evaluation, batched-protocol defaults, fault handling, the
vectorized evaluate_many fast path, and the CLI."""

import json
import math
import random
import threading

import pytest

from repro.core.costmodel import (ARCH_NAMES, KernelFeatures,
                                  estimate_seconds, estimate_seconds_many)
from repro.core.problem import FunctionProblem, Trial, TunableProblem
from repro.core.space import Param, SearchSpace
from repro.core.tuners import (TUNERS, DifferentialEvolution,
                               GeneticAlgorithm, ParticleSwarm, RandomSearch,
                               run_tuner)
from repro.orchestrator import (Campaign, JobQueue, SessionSpec, SessionStore,
                                WorkerPool, make_problem, run_session)
from repro.orchestrator.cli import main as cli_main
from repro.orchestrator.queue import DONE as JOB_DONE
from repro.orchestrator.queue import POISONED
from repro.orchestrator.runner import resume_session

ALL_TUNER_NAMES = sorted(TUNERS)


def _quad_problem(n_params=4, k=8, record=None):
    params = [Param(f"p{i}", tuple(range(k))) for i in range(n_params)]
    space = SearchSpace(params, name="quad")

    def fn(cfg, arch):
        if record is not None:
            record.append(tuple(cfg[f"p{i}"] for i in range(n_params)))
        return 1.0 + sum((cfg[f"p{i}"] - 2) ** 2 for i in range(n_params))

    return FunctionProblem(space, fn, name="quad")


def _traces_equal(a, b):
    return ([t.config for t in a.trials] == [t.config for t in b.trials]
            and [t.objective for t in a.trials] == [t.objective for t in b.trials])


# --------------------------------------------------------------------- #
# parallel session == serial run_tuner
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("tname", ["random", "grid", "local", "annealing",
                                   "surrogate_bo"])
def test_parallel_session_bitforbit_vs_serial(tname):
    """≥4 workers: identical best, trial count, and convergence curve for
    every tuner whose ask stream is batch-invariant (the acceptance
    criterion; population tuners intentionally switch to generational
    batches and are covered separately)."""
    prob = _quad_problem()
    serial = run_tuner(TUNERS[tname](prob.space, seed=3), prob, budget=40)
    spec = SessionSpec(problem="quad", tuner=tname, budget=40, seed=3,
                       workers=4)
    par = run_session(spec, problem=prob)
    assert _traces_equal(serial, par)
    assert par.best.objective == serial.best.objective
    assert par.best.config == serial.best.config
    assert par.best_curve() == serial.best_curve()


@pytest.mark.parametrize("tname", ALL_TUNER_NAMES)
def test_session_deterministic_across_worker_counts(tname):
    """Batch width is set by the tuner, not the pool, so the trajectory is
    a pure function of the spec — identical at 1 and 8 workers."""
    prob = _quad_problem()

    def go(workers):
        spec = SessionSpec(problem="quad", tuner=tname, budget=30, seed=11,
                           workers=workers)
        return run_session(spec, problem=prob,
                           tuner=TUNERS[tname](prob.space, seed=11))

    assert _traces_equal(go(1), go(8))


def test_unique_false_grid_exhaustion_worker_independent():
    """Even with unique=False (cache hits consume budget) and an exhausted
    grid emitting random fallbacks, the recorded trace must not depend on
    worker count — batch width comes from the tuner, never the pool."""
    prob = _quad_problem(n_params=2, k=4)       # 16-config grid, budget 24

    def go(workers):
        spec = SessionSpec(problem="quad", tuner="grid", budget=24, seed=0,
                           workers=workers, unique=False)
        return run_session(spec, problem=prob)

    a, b = go(1), go(4)
    assert len(a.trials) == len(b.trials)
    assert _traces_equal(a, b)


def test_dedup_budget_semantics_match_serial():
    prob = _quad_problem(n_params=1, k=4)          # tiny space forces dups
    serial = run_tuner(RandomSearch(prob.space, seed=0), prob, budget=50)
    spec = SessionSpec(problem="quad", tuner="random", budget=50, seed=0,
                       workers=4)
    par = run_session(spec, problem=prob)
    assert len(par.trials) == len(serial.trials) == 4


# --------------------------------------------------------------------- #
# batched protocol defaults
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("tname", ALL_TUNER_NAMES)
def test_ask_tell_batch_defaults_equal_serial_protocol(tname):
    """Driving any tuner through ask_batch(1)/tell_batch must be
    indistinguishable from the plain ask/tell loop."""
    prob = _quad_problem()
    a = TUNERS[tname](prob.space, seed=9)
    b = TUNERS[tname](prob.space, seed=9)
    for _ in range(30):
        ca = a.ask()
        cb = b.ask_batch(1)
        assert [ca] == cb
        t = prob.evaluate(ca)
        a.tell(t)
        b.tell_batch([t])


@pytest.mark.parametrize("cls,width", [(GeneticAlgorithm, 20),
                                       (DifferentialEvolution, 20),
                                       (ParticleSwarm, 12)])
def test_population_tuners_native_batch(cls, width):
    """Population tuners expose their population as the safe batch width
    and stay consistent over whole-generation ask/tell cycles."""
    prob = _quad_problem(n_params=3, k=6)
    tuner = cls(prob.space, seed=4)
    assert tuner.max_parallel_asks == width
    best = math.inf
    for _ in range(6):                      # 6 generations
        cfgs = tuner.ask_batch(width)
        assert len(cfgs) == width
        assert all(prob.space.satisfies(c) for c in cfgs)
        trials = prob.evaluate_many(cfgs)
        tuner.tell_batch(trials)
        best = min(best, min(t.objective for t in trials))
    assert best < 4.0                       # made real progress on the quad


def test_population_session_converges_in_parallel():
    prob = _quad_problem(n_params=3, k=4)   # |S| = 64
    spec = SessionSpec(problem="quad", tuner="genetic", budget=64, seed=1,
                       workers=8)
    res = run_session(spec, problem=prob)
    assert res.best.objective == pytest.approx(1.0)
    curve = res.best_curve()
    assert all(b2 <= b1 + 1e-12 for b1, b2 in zip(curve, curve[1:]))


# --------------------------------------------------------------------- #
# resume
# --------------------------------------------------------------------- #
def test_resume_skips_journaled_configs(tmp_path):
    """Kill-and-resume: the resumed run must re-evaluate nothing from the
    journal and finish bit-for-bit equal to an uninterrupted run."""
    evals = []
    prob = _quad_problem(record=evals)
    store = SessionStore(tmp_path)
    spec = SessionSpec(problem="quad", tuner="random", budget=30, seed=5,
                       workers=4)

    partial = run_session(spec, problem=prob, store=store, stop_after=12)
    # stop_after lands on the next batch boundary (unbounded cap = 16)
    assert len(partial.trials) == 16
    assert store.meta(spec.session_id)["status"] == "interrupted"
    phase1 = list(evals)
    assert len(phase1) == 16

    full = run_session(spec, problem=prob, store=store)
    phase2 = evals[len(phase1):]
    assert len(full.trials) == 30
    assert store.meta(spec.session_id)["status"] == "done"
    # nothing evaluated twice — the journal answered the replayed prefix
    assert not set(phase1) & set(phase2)
    assert len(phase1) + len(phase2) == 30

    ref = run_tuner(RandomSearch(prob.space, seed=5), _quad_problem(),
                    budget=30)
    assert _traces_equal(ref, full)


@pytest.mark.parametrize("tname", ["genetic", "diffevo", "pso", "local",
                                   "annealing", "surrogate_bo"])
def test_resume_exact_for_stateful_tuners(tmp_path, tname):
    """Resume replays the journal through the tuner, reconstructing its RNG
    state: resumed trace == never-interrupted trace, zero re-evaluations.
    stop_after=25 cuts *past* the first generation boundary of the
    population tuners — the case that requires batch-aligned stops.
    surrogate_bo is the rng-stream-contract regression: its ask draws a
    variable-length sequence (candidate pool sampling), which resume must
    replay identically through the model-refit schedule."""
    evals = []
    prob = _quad_problem(record=evals)
    store = SessionStore(tmp_path / tname)
    spec = SessionSpec(problem="quad", tuner=tname, budget=45, seed=2,
                       workers=4)

    run_session(spec, problem=prob, store=store, stop_after=25)
    n1 = len(evals)
    full = run_session(spec, problem=prob, store=store)
    assert not set(evals[:n1]) & set(evals[n1:])

    uninterrupted = run_session(spec, problem=_quad_problem())
    assert _traces_equal(uninterrupted, full)


@pytest.mark.parametrize("stop", [10, 27, 38])
def test_resume_exact_for_batched_surrogate_bo(tmp_path, stop):
    """Batched qLCB asks draw per-slot kappa jitter; the final batch is
    budget-truncated.  Resume must replay the identical draw stream at
    every stop boundary (the rng-stream contract in tuners/base.py)."""
    prob = _quad_problem()
    store = SessionStore(tmp_path / f"bo{stop}")
    spec = SessionSpec(problem="quad", tuner="surrogate_bo", budget=42,
                       seed=3, workers=2,
                       tuner_kwargs={"n_init": 8, "batch_width": 4})
    run_session(spec, problem=prob, store=store, stop_after=stop)
    resumed = run_session(spec, problem=prob, store=store)
    uninterrupted = run_session(spec, problem=_quad_problem())
    assert _traces_equal(uninterrupted, resumed)


def test_resume_session_api_and_torn_journal(tmp_path):
    """A crash mid-append tears one journal line; records appended after
    the tear must survive a *second* resume (no gluing, no truncation)."""
    evals = []
    prob = _quad_problem(record=evals)
    store = SessionStore(tmp_path)
    spec = SessionSpec(problem="toy_quad", tuner="random", budget=40, seed=0,
                       workers=2)
    run_session(spec, problem=prob, store=store, stop_after=8)
    # simulate a crash mid-append: torn, newline-less final line
    jp = store._journal_path(spec.session_id)
    with open(jp, "a") as f:
        f.write('{"k": 123, "c": [')
    run_session(spec, problem=prob, store=store, stop_after=20)
    n2 = len(evals)
    assert n2 > 16                # fresh records landed after the tear
    full = resume_session(spec.session_id, store)
    assert len(full.trials) == 40
    assert store.meta(spec.session_id)["status"] == "done"
    # the final resume re-evaluates nothing journaled before or after the tear
    assert not set(evals[:n2]) & set(evals[n2:])


def test_finished_session_publishes_trace(tmp_path):
    store = SessionStore(tmp_path)
    prob = _quad_problem()
    spec = SessionSpec(problem="quad", tuner="random", budget=15, seed=1,
                       workers=2)
    res = run_session(spec, problem=prob, store=store)
    table = store.tables.get("quad", "v5e", f"session_{spec.session_id}")
    assert len(table) == len(res.trials)
    assert table.best()[1] == res.best.objective
    assert table.meta["tuner"] == "random"


# --------------------------------------------------------------------- #
# fault handling
# --------------------------------------------------------------------- #
def test_poison_config_marked_invalid_after_retries():
    params = [Param("a", (0, 1, 2, 3))]
    space = SearchSpace(params, name="poison")
    attempts = {}
    lock = threading.Lock()

    def fn(cfg, arch):
        if cfg["a"] == 2:
            with lock:
                attempts["n"] = attempts.get("n", 0) + 1
            raise RuntimeError("kaboom")
        return float(cfg["a"] + 1)

    prob = FunctionProblem(space, fn, name="poison")
    spec = SessionSpec(problem="poison", tuner="grid", budget=4, seed=0,
                       workers=2)
    res = run_session(spec, problem=prob, max_retries=2)
    assert len(res.trials) == 4
    bad = [t for t in res.trials if not t.valid]
    assert len(bad) == 1
    assert bad[0].config["a"] == 2
    assert bad[0].info.get("poison") is True
    # one chunked attempt, then first try + 2 retries on the isolation path
    assert attempts["n"] == 4
    assert res.best.objective == 1.0        # the rest of the grid survived


def test_transient_failure_requeued_and_recovered():
    params = [Param("a", tuple(range(6)))]
    space = SearchSpace(params, name="flaky")
    failed_once = set()
    lock = threading.Lock()

    def fn(cfg, arch):
        with lock:
            if cfg["a"] not in failed_once:
                failed_once.add(cfg["a"])
                raise OSError("transient worker death")
        return float(cfg["a"])

    prob = FunctionProblem(space, fn, name="flaky")
    spec = SessionSpec(problem="flaky", tuner="grid", budget=6, seed=0,
                       workers=3)
    res = run_session(spec, problem=prob, max_retries=1)
    assert len(res.trials) == 6
    assert all(t.valid for t in res.trials)
    assert res.best.objective == 0.0


class _WorkerKiller(TunableProblem):
    """Picklable problem whose a==1 config kills its worker process."""

    name = "killer"

    def __init__(self):
        super().__init__(SearchSpace([Param("a", (0, 1, 2, 3))], name="k"))

    def evaluate(self, config, arch="v5e"):
        if config["a"] == 1:
            import os
            os._exit(13)               # simulated OOM/segfault
        return Trial(config, float(config["a"] + 1), arch)


def test_process_worker_death_poisons_config_not_session():
    """A config that takes down its worker process must end up poisoned
    while the session completes on a rebuilt pool."""
    prob = _WorkerKiller()
    spec = SessionSpec(problem="killer", tuner="grid", budget=4, seed=0,
                       workers=2)
    res = run_session(spec, problem=prob, mode="process", max_retries=1)
    assert len(res.trials) == 4
    bad = [t for t in res.trials if not t.valid]
    assert [t.config["a"] for t in bad] == [1]
    assert bad[0].info.get("poison") is True
    ok = sorted(t.objective for t in res.trials if t.valid)
    assert ok == [1.0, 3.0, 4.0]


def test_session_marked_failed_on_crash(tmp_path):
    store = SessionStore(tmp_path)
    prob = _quad_problem()
    spec = SessionSpec(problem="quad", tuner="random", budget=20, seed=0,
                       workers=2)

    def boom(res):
        raise RuntimeError("driver crash")

    with pytest.raises(RuntimeError, match="driver crash"):
        run_session(spec, problem=prob, store=store, on_batch=boom)
    assert store.meta(spec.session_id)["status"] == "failed"
    # the journaled batch survives: a later resume just continues
    full = run_session(spec, problem=prob, store=store)
    assert len(full.trials) == 20
    assert store.meta(spec.session_id)["status"] == "done"


def test_jobqueue_retry_cap_and_poison():
    q = JobQueue(max_retries=2)
    q.submit(7, {"a": 1})
    job = q.take()
    assert q.fail(job, "err1") is True      # requeued
    job = q.take()
    assert q.fail(job, "err2") is True
    job = q.take()
    assert q.fail(job, "err3") is False     # poisoned
    assert q.job(7).state == POISONED
    assert q.drained()
    # dedup: resubmitting the same key returns the same job
    assert q.submit(7, {"a": 1}).state == POISONED
    q.submit(8, {"a": 2})
    job = q.take()
    q.complete(job, "ok")
    assert q.job(8).state == JOB_DONE
    assert q.counts()[POISONED] == 1


# --------------------------------------------------------------------- #
# vectorized fast path
# --------------------------------------------------------------------- #
class _AnalyticalToy(TunableProblem):
    """Exercises the evaluate_many fast path (features + cost model)."""

    name = "analytical_toy"

    def __init__(self):
        super().__init__(SearchSpace(
            [Param("block", (8, 64, 128, 512)), Param("unroll", (1, 2, 8))],
            name="atoy"))

    def features(self, config, arch):
        b = config["block"]
        return KernelFeatures(
            mxu_flops=2.0 * 4096 ** 3 / 64,
            hbm_bytes=2.0 * 4096 * 4096 * (1 + 512 / b),
            vmem_working_set=b * b * 48.0,
            grid_steps=(4096 / b) ** 2,
            mxu_tile=(b, b, 512), dtype_bytes=2,
            unroll=config["unroll"], inner_trip=b // 8)


def test_evaluate_many_matches_scalar_evaluate():
    prob = _AnalyticalToy()
    cfgs = list(prob.space.enumerate())
    for arch in ARCH_NAMES:
        batch = prob.evaluate_many(cfgs, arch)
        for cfg, t in zip(cfgs, batch):
            ref = prob.evaluate(cfg, arch)
            assert t.objective == ref.objective
            assert t.valid == ref.valid


def test_estimate_seconds_many_matches_scalar():
    rng = random.Random(1)
    feats = [KernelFeatures(
        mxu_flops=rng.choice([0.0, rng.uniform(1e9, 1e13)]),
        vpu_flops=rng.choice([0.0, rng.uniform(1e6, 1e11)]),
        transcendental_ops=rng.uniform(0, 1e9),
        hbm_bytes=rng.uniform(1e3, 1e10),
        vmem_working_set=rng.uniform(0, 220 * 1024 * 1024),
        grid_steps=rng.uniform(1, 1e5),
        mxu_tile=(rng.choice([8, 128, 1000]), rng.choice([8, 512]),
                  rng.choice([32, 4096])),
        dtype_bytes=rng.choice([1, 2, 4]),
        lane_extent=rng.choice([1, 100, 257]),
        sublane_extent=rng.choice([1, 8, 33]),
        unroll=rng.choice([1, 8, 64]), inner_trip=rng.choice([0, 1, 100]),
        serialization=rng.uniform(-0.2, 1.3),
        gather_bytes=rng.choice([0.0, 1e8]),
    ) for _ in range(100)]
    for arch in ARCH_NAMES:
        vec = estimate_seconds_many(feats, arch)
        for f, v in zip(feats, vec):
            s = estimate_seconds(f, arch)
            assert (math.isinf(s) and math.isinf(v)) or s == v
    assert estimate_seconds_many([], "v5e") == []


def test_function_problem_keeps_loop_path():
    calls = []
    prob = _quad_problem(record=calls)
    trials = prob.evaluate_many(prob.space.sample_batch(5, seed=0))
    assert len(trials) == len(calls) == 5


def test_evaluate_many_flags_constraint_violations():
    from repro.core.space import Constraint
    space = SearchSpace([Param("a", (1, 2, 3, 4))],
                        [Constraint("even", lambda c: c["a"] % 2 == 0)])

    class P(_AnalyticalToy):
        def __init__(self):
            TunableProblem.__init__(self, space)

        def features(self, config, arch):
            return KernelFeatures(vpu_flops=1e9, hbm_bytes=1e6)

    trials = P().evaluate_many([{"a": v} for v in (1, 2, 3, 4)])
    assert [t.valid for t in trials] == [False, True, False, True]
    assert trials[0].info["violated"] == ["even"]


# --------------------------------------------------------------------- #
# worker pool
# --------------------------------------------------------------------- #
def test_worker_pool_preserves_order():
    import time as _time
    params = [Param("a", tuple(range(16)))]
    space = SearchSpace(params, name="order")

    def fn(cfg, arch):                       # earlier configs finish later
        _time.sleep((16 - cfg["a"]) * 0.002)
        return float(cfg["a"])

    prob = FunctionProblem(space, fn, name="order")
    with WorkerPool(prob, "v5e", workers=8) as pool:
        trials = pool.evaluate([{"a": i} for i in range(16)])
    assert [t.objective for t in trials] == [float(i) for i in range(16)]


def test_worker_pool_mode_selection():
    from repro.core.problem import MeasuredProblem
    space = SearchSpace([Param("a", (1, 2))], name="m")
    measured = MeasuredProblem(space, build=lambda cfg: (lambda: None))
    assert WorkerPool(measured, "cpu").mode == "process"
    assert WorkerPool(_quad_problem(), "v5e").mode == "thread"
    with pytest.raises(ValueError):
        WorkerPool(_quad_problem(), "v5e", mode="rayon")


# --------------------------------------------------------------------- #
# results cachefile format (optional orjson/zstandard)
# --------------------------------------------------------------------- #
def test_result_table_roundtrip_with_available_codecs(tmp_path):
    from repro.core import results
    from repro.core.results import ResultsDB, ResultTable

    table = ResultTable(problem="p", arch="v5e", param_names=("a",),
                        configs=[(0,), (1,)], objectives=[1.5, math.inf],
                        protocol="exhaustive", meta={"note": "x"})
    raw = table.to_bytes()
    if results.zstandard is None:
        assert raw[0] == 0x78             # zlib header, not the zstd magic
    else:
        assert raw[:4] == results._ZSTD_MAGIC
    back = ResultTable.from_bytes(raw)
    assert back.configs == table.configs
    assert back.objectives == table.objectives

    db = ResultsDB(tmp_path)
    db.put(table)
    assert db.get("p", "v5e", "exhaustive").objectives == table.objectives


def test_zlib_cachefile_loads_regardless_of_zstd():
    """A stdlib-written file must load on any install (format sniffing)."""
    import zlib

    from repro.core.results import _load
    payload = json.dumps({"ok": 1}).encode()
    assert _load(zlib.compress(payload, 6)) == {"ok": 1}


def test_zstd_cachefile_fails_loudly_without_zstandard():
    from repro.core import results
    if results.zstandard is not None:
        pytest.skip("zstandard installed: the fast path handles this")
    with pytest.raises(RuntimeError, match="zstd"):
        results._load(results._ZSTD_MAGIC + b"\x00\x01")


# --------------------------------------------------------------------- #
# sessions, campaigns, CLI
# --------------------------------------------------------------------- #
def test_session_spec_identity_and_roundtrip():
    a = SessionSpec(problem="gemm", tuner="genetic", budget=100, seed=0)
    b = SessionSpec.from_json(json.loads(json.dumps(a.to_json())))
    assert a.session_id == b.session_id
    assert SessionSpec(problem="gemm", tuner="genetic", budget=100,
                       seed=1).session_id != a.session_id


def test_registry_toy_problems():
    prob = make_problem("toy_rastrigin")
    assert prob.space.cardinality == 10 ** 4
    with pytest.raises(KeyError):
        make_problem("nope")


def test_campaign_grid_runs_and_resumes(tmp_path):
    store = SessionStore(tmp_path)
    camp = Campaign.grid(problems=["toy_quad"], tuners=["random", "genetic"],
                         seeds=range(2), budget=25, workers=2)
    assert len(camp) == 4
    results = camp.run(store)
    assert len(results) == 4
    assert camp.done(store)
    rows = camp.status(store)
    assert all(r["status"] == "done" and r["evaluated"] == 25 for r in rows)
    # second run is a pure journal replay: same results, no new evaluations
    again = camp.run(store)
    for sid in results:
        assert _traces_equal(results[sid], again[sid])


# --------------------------------------------------------------------- #
# journal v2: row-native records, info round-trip, v1 compat
# --------------------------------------------------------------------- #
def test_journal_v2_records_are_row_native(tmp_path):
    store = SessionStore(tmp_path)
    prob = _quad_problem()
    spec = SessionSpec(problem="quad", tuner="random", budget=12, seed=7,
                       workers=2)
    run_session(spec, problem=prob, store=store)
    lines = store._journal_path(spec.session_id).read_text().splitlines()
    recs = [json.loads(l) for l in lines]
    assert recs
    for rec in recs:
        assert set(rec) <= {"k", "o", "v", "i"}     # no "c" column
        assert rec["k"] == prob.space.flat_index(
            prob.space.from_flat_index(rec["k"]))


def test_journal_v2_resume_replays_fault_info(tmp_path):
    """The satellite bug: poison markers (poison/attempts/error) must
    survive the journal round-trip, so a resumed trace is info-identical
    to the never-interrupted run."""
    params = [Param("a", tuple(range(24)))]
    space = SearchSpace(params, name="poisonj")

    def fn(cfg, arch):
        if cfg["a"] % 5 == 2:                     # several poison configs
            raise RuntimeError(f"kaboom {cfg['a']}")
        return float(cfg["a"] + 1)

    def mk():
        return FunctionProblem(SearchSpace([Param("a", tuple(range(24)))],
                                           name="poisonj"), fn,
                               name="poisonj")

    store = SessionStore(tmp_path)
    spec = SessionSpec(problem="poisonj", tuner="grid", budget=24, seed=0,
                       workers=2)
    run_session(spec, problem=mk(), store=store, stop_after=10,
                max_retries=1)
    resumed = run_session(spec, problem=mk(), store=store, max_retries=1)
    uninterrupted = run_session(spec, problem=mk(), max_retries=1)

    assert _traces_equal(uninterrupted, resumed)
    assert [t.info for t in resumed.trials] == \
           [t.info for t in uninterrupted.trials]
    poisoned = [t for t in resumed.trials if t.info.get("poison")]
    assert len(poisoned) == 5                      # a in {2,7,12,17,22}
    for t in poisoned:
        assert t.info["attempts"] == 2
        assert "kaboom" in t.info["error"]


def test_journal_v1_records_still_load(tmp_path):
    """A v1 journal (explicit encoded-config column) written by an older
    build must resume exactly."""
    evals = []
    prob = _quad_problem(record=evals)
    store = SessionStore(tmp_path)
    spec = SessionSpec(problem="quad", tuner="random", budget=30, seed=5,
                       workers=4)
    run_session(spec, problem=prob, store=store, stop_after=12)
    jp = store._journal_path(spec.session_id)

    # rewrite the journal in the v1 format
    v1_lines = []
    for line in jp.read_text().splitlines():
        rec = json.loads(line)
        cfg = prob.space.from_flat_index(rec["k"])
        v1 = {"k": rec["k"], "c": list(prob.space.encode(cfg)),
              "o": rec["o"], "v": rec["v"]}
        v1_lines.append(json.dumps(v1, separators=(",", ":")))
    jp.write_text("\n".join(v1_lines) + "\n")

    n1 = len(evals)
    full = run_session(spec, problem=prob, store=store)
    assert len(full.trials) == 30
    assert not set(evals[:n1]) & set(evals[n1:])   # nothing re-evaluated
    ref = run_tuner(RandomSearch(prob.space, seed=5), _quad_problem(),
                    budget=30)
    assert _traces_equal(ref, full)


def test_json_safe_info_filter():
    from repro.orchestrator.store import _json_safe_info

    class Blob:                                    # not JSON-serializable
        pass

    info = {"error": "boom", "poison": True, "attempts": 3,
            "violated": ["c1", "c2"], "nested": {"a": 1.5, "b": [1, "x"]},
            "features": Blob(), "inf": math.inf, "none": None}
    safe = _json_safe_info(info)
    assert safe == {"error": "boom", "poison": True, "attempts": 3,
                    "violated": ["c1", "c2"],
                    "nested": {"a": 1.5, "b": [1, "x"]}, "none": None}
    assert json.loads(json.dumps(safe)) == safe


def test_trial_lazy_config_and_materialize():
    from repro.core.problem import materialize_configs
    prob = _quad_problem(n_params=2, k=4)
    space = prob.space
    space.compile_eagerly()
    lazy = [Trial(None, 1.0, "v5e", row=r, space=space) for r in (3, 7, 11)]
    assert all(t._config is None for t in lazy)
    assert [t.row for t in lazy] == [3, 7, 11]
    materialize_configs(lazy)
    for t, r in zip(lazy, (3, 7, 11)):
        assert t._config is not None
        assert t.config == space.from_flat_index(r)
    with pytest.raises(ValueError):
        Trial(None, 1.0, "v5e")                    # lazy needs row+space
    # eager trials may carry their row too (journal/publish fast path)
    t = Trial({"a": 1}, 2.0, "v5e", row=9, space=space)
    assert t.config == {"a": 1} and t.row == 9


# --------------------------------------------------------------------- #
# empty ask == finished (the cfgs[0] crash)
# --------------------------------------------------------------------- #
def _stub_tuner_class(rows_mode: bool):
    from repro.core.tuners.base import Tuner

    class Stub(Tuner):
        """Returns one short batch, then empty asks (exhaustion flipping
        mid-batch) — the dict path used to crash on ``cfgs[0]``."""
        name = "stub"
        max_parallel_asks = None

        def __init__(self, space, seed=0):
            super().__init__(space, seed)
            self._served = False
            if not rows_mode:
                self._comp = None      # force the dict path

        def ask_scalar(self):
            return self.space.from_flat_index(0)

        def ask_batch(self, n):
            if self._served:
                return []
            self._served = True
            return [self.space.from_flat_index(i) for i in range(3)]

        def ask_rows(self, n):
            if self._served:
                return []
            self._served = True
            return [0, 1, 2]

    return Stub


@pytest.mark.parametrize("rows_mode", [False, True])
def test_empty_ask_batch_treated_as_finished(tmp_path, rows_mode):
    prob = _quad_problem(n_params=2, k=4)
    store = SessionStore(tmp_path)
    spec = SessionSpec(problem="quad", tuner="stub", budget=20, seed=0,
                       workers=2)
    tuner = _stub_tuner_class(rows_mode)(prob.space, seed=0)
    assert tuner.index_native == rows_mode
    res = run_session(spec, problem=prob, tuner=tuner, store=store)
    # the short batch landed, the empty ask ended the session cleanly
    assert len(res.trials) == 3
    assert store.meta(spec.session_id)["status"] == "done"


def test_immediately_empty_ask_is_clean_noop():
    prob = _quad_problem(n_params=2, k=4)
    Stub = _stub_tuner_class(False)
    tuner = Stub(prob.space, seed=0)
    tuner._served = True                           # empty from the first ask
    spec = SessionSpec(problem="quad", tuner="stub", budget=20, seed=0,
                       workers=2)
    res = run_session(spec, problem=prob, tuner=tuner)
    assert res.trials == []


# --------------------------------------------------------------------- #
# publish-before-DONE (the lost-table crash window)
# --------------------------------------------------------------------- #
def test_trace_published_before_done_mark(tmp_path):
    calls = []
    store = SessionStore(tmp_path)
    orig_publish, orig_update = store.publish_trace, store.update_meta
    store.publish_trace = lambda *a, **k: (calls.append("publish"),
                                           orig_publish(*a, **k))[1]
    store.update_meta = lambda sid, **f: (
        calls.append(f"meta:{f.get('status')}") or orig_update(sid, **f))
    prob = _quad_problem()
    spec = SessionSpec(problem="quad", tuner="random", budget=10, seed=1,
                       workers=2)
    run_session(spec, problem=prob, store=store)
    assert "publish" in calls
    assert calls.index("publish") < calls.index("meta:done")


def test_crash_between_publish_and_done_is_resumable(tmp_path):
    """A crash in the publish→DONE window must leave a resumable session
    whose table already exists; resume republishes idempotently and
    finishes DONE."""
    store = SessionStore(tmp_path)
    prob = _quad_problem()
    spec = SessionSpec(problem="quad", tuner="random", budget=10, seed=1,
                       workers=2)
    orig = store.update_meta

    def boom_on_done(sid, **fields):
        if fields.get("status") == "done":
            raise OSError("crash before the DONE mark")
        return orig(sid, **fields)

    store.update_meta = boom_on_done
    with pytest.raises(OSError):
        run_session(spec, problem=prob, store=store)
    # the table survived the crash; the session is not a lost DONE husk
    table = store.tables.get("quad", "v5e", f"session_{spec.session_id}")
    assert len(table) == 10
    assert store.meta(spec.session_id)["status"] == "failed"

    store.update_meta = orig
    res = run_session(spec, problem=prob, store=store)  # == resume_session
    assert len(res.trials) == 10
    assert store.meta(spec.session_id)["status"] == "done"
    table = store.tables.get("quad", "v5e", f"session_{spec.session_id}")
    assert table.best()[1] == res.best.objective


def test_cli_campaign_runs_grid(tmp_path, capsys):
    store_dir = str(tmp_path / "camp_store")
    rc = cli_main(["campaign", "--problems", "toy_quad",
                   "--tuners", "random,genetic", "--archs", "v5e,v4",
                   "--seeds", "0,1", "--budget", "20", "--workers", "2",
                   "--store", store_dir])
    assert rc == 0
    out = capsys.readouterr().out
    assert "8 sessions" in out
    assert out.count("done") == 8
    rc = cli_main(["campaign", "--problems", "nope", "--tuners", "random",
                   "--store", store_dir])
    assert rc == 2
    capsys.readouterr()
    rc = cli_main(["campaign", "--problems", "toy_quad", "--tuners", "zzz",
                   "--store", store_dir])
    assert rc == 2
    capsys.readouterr()


def test_cli_submit_status_resume(tmp_path, capsys):
    store_dir = str(tmp_path / "cli_store")
    rc = cli_main(["submit", "--problem", "toy_quad", "--tuner", "random",
                   "--budget", "18", "--seed", "3", "--workers", "2",
                   "--store", store_dir, "--stop-after", "7"])
    assert rc == 0
    sid = capsys.readouterr().out.split()[1]

    rc = cli_main(["status", "--store", store_dir])
    assert rc == 0
    out = capsys.readouterr().out
    # stop-after 7 rounds up to the 16-wide unbounded batch boundary
    assert sid in out and "interrupted" in out and "16/18" in out

    rc = cli_main(["resume", sid, "--store", store_dir])
    assert rc == 0
    assert "18 trials" in capsys.readouterr().out

    rc = cli_main(["status", sid, "--store", store_dir])
    assert "done" in capsys.readouterr().out and rc == 0

    assert cli_main(["resume", "missing", "--store", store_dir]) == 2
    capsys.readouterr()
    assert cli_main(["submit", "--problem", "toy_quad", "--tuner", "random",
                     "--store", store_dir, "--tuner-kwargs", "{bad"]) == 2
