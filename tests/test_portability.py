"""Portability campaign engine: arch-shared evaluation correctness, the
transfer-matrix table vs brute force, and the interleaved multi-session
scheduler's equivalence with the serial campaign loop."""

import math

import pytest

from repro.core import spacetable

_prev_cache = spacetable.get_cache_dir()
from benchmarks.table_portability import transfer_matrix  # noqa: E402

spacetable.set_cache_dir(_prev_cache)   # undo benchmarks.common's global

from repro.core.costmodel import ARCH_NAMES  # noqa: E402
from repro.core.problem import FunctionProblem  # noqa: E402
from repro.core.space import Param, SearchSpace  # noqa: E402
from repro.orchestrator import (Campaign, SessionStore, WorkerPool,  # noqa: E402
                                run_campaign, run_session)


def _small_problem(name):
    from repro.kernels.nbody.space import NbodyProblem
    from repro.kernels.pnpoly.space import PnpolyProblem
    return {"nbody": NbodyProblem, "pnpoly": PnpolyProblem}[name]()


# --------------------------------------------------------------------- #
# transfer matrix == brute force
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["nbody", "pnpoly"])
def test_transfer_matrix_matches_bruteforce(name):
    """The arch-shared table must agree with the definition computed the
    slow way: per-arch exhaustive minima via scalar ``evaluate`` calls."""
    prob = _small_problem(name)
    m = transfer_matrix(prob, ARCH_NAMES)

    cfgs = prob.space.valid_configs()
    objs = {a: [prob.evaluate(c, a).objective for c in cfgs]
            for a in ARCH_NAMES}
    best_i = {a: min(range(len(cfgs)),
                     key=lambda j: objs[a][j] if math.isfinite(objs[a][j])
                     else math.inf)
              for a in ARCH_NAMES}
    for i, src in enumerate(ARCH_NAMES):
        for j, dst in enumerate(ARCH_NAMES):
            t = objs[dst][best_i[src]]
            want = (100.0 * objs[dst][best_i[dst]] / t
                    if math.isfinite(t) else 0.0)
            assert m["matrix_pct"][i][j] == pytest.approx(want, rel=1e-12), \
                (src, dst)
    # the source optima the matrix used are the true per-arch optima
    for a in ARCH_NAMES:
        assert m["best_seconds"][a] == objs[a][best_i[a]]


def test_transfer_matrix_diagonal_and_bounds():
    prob = _small_problem("nbody")
    m = transfer_matrix(prob, ARCH_NAMES)
    for i in range(len(ARCH_NAMES)):
        assert m["matrix_pct"][i][i] == pytest.approx(100.0)
        for j in range(len(ARCH_NAMES)):
            assert 0.0 <= m["matrix_pct"][i][j] <= 100.0 + 1e-9


# --------------------------------------------------------------------- #
# arch-shared pool evaluation
# --------------------------------------------------------------------- #
def test_evaluate_rows_archs_bitidentical_to_single_arch_pools():
    """One archs= call must equal four independent single-arch pools,
    objective for objective and validity for validity."""
    prob = _small_problem("pnpoly")
    comp = prob.space.compile_eagerly()
    rows = [int(r) for r in comp.valid_rows[:300]]
    with WorkerPool(prob, ARCH_NAMES[0], workers=3) as pool:
        shared = pool.evaluate_rows(rows, archs=ARCH_NAMES)
    for a in ARCH_NAMES:
        with WorkerPool(prob, a, workers=2) as solo:
            single = solo.evaluate_rows(rows)
        assert [t.objective for t in shared[a]] == \
               [t.objective for t in single]
        assert [t.valid for t in shared[a]] == [t.valid for t in single]
    # arch-shared trials are row-backed and lazy: no config was decoded
    t = shared[ARCH_NAMES[0]][0]
    assert t.row == rows[0]
    assert t._config is None
    assert t.config == comp.decode_row(rows[0])


def test_evaluate_rows_archs_counts_one_feature_pass():
    """The sharing criterion: rows through the feature computation <= the
    unique row count — NOT archs x rows."""
    from repro.kernels.pnpoly.space import PnpolyProblem
    counts = {"rows": 0}

    class Counting(PnpolyProblem):
        def feature_columns(self, cols, arch):
            counts["rows"] += len(next(iter(cols.values()))) if cols else 0
            return super().feature_columns(cols, arch)

        def features(self, config, arch):
            counts["rows"] += 1
            return super().features(config, arch)

    prob = Counting()
    comp = prob.space.compile_eagerly()
    rows = [int(r) for r in comp.valid_rows[:200]]
    prob.trials_for_rows_archs(rows, ARCH_NAMES)
    assert counts["rows"] <= len(rows)


def test_evaluate_rows_archs_poison_isolated_per_arch():
    """A row whose evaluation raises must come back poisoned on every arch
    without wedging the batch."""
    space = SearchSpace([Param("a", tuple(range(8)))], name="pp")

    def fn(cfg, arch):
        if cfg["a"] == 3:
            raise RuntimeError("kaboom")
        return float(cfg["a"] + 1)

    prob = FunctionProblem(space, fn, name="pp")
    prob.space.compile_eagerly()
    with WorkerPool(prob, "v5e", workers=2, max_retries=1) as pool:
        shared = pool.evaluate_rows(list(range(8)), archs=("v5e", "v4"))
    for a in ("v5e", "v4"):
        bad = [t for t in shared[a] if not t.valid]
        assert len(bad) == 1
        assert bad[0].config["a"] == 3
        assert bad[0].info.get("poison") is True
        ok = [t.objective for t in shared[a] if t.valid]
        assert ok == [1.0, 2.0, 3.0, 5.0, 6.0, 7.0, 8.0]


# --------------------------------------------------------------------- #
# interleaved campaign scheduler
# --------------------------------------------------------------------- #
def _record_problem(record):
    space = SearchSpace([Param(f"p{i}", tuple(range(6))) for i in range(3)],
                        name="camp_quad")

    offs = {"v5e": 0.0, "v4": 0.1, "v5p": 0.2}

    def fn(cfg, arch):
        record.append((tuple(cfg[f"p{i}"] for i in range(3)), arch))
        return 1.0 + sum((cfg[f"p{i}"] - 2) ** 2 for i in range(3)) \
            + offs.get(arch, 0.3)

    return FunctionProblem(space, fn, name="camp_quad")


def _traces_equal(a, b):
    return ([t.config for t in a.trials] == [t.config for t in b.trials]
            and [t.objective for t in a.trials]
            == [t.objective for t in b.trials])


def test_empty_campaign_is_clean_noop():
    assert run_campaign([]) == {}
    assert Campaign([]).run(interleave=True) == {}


@pytest.mark.parametrize("tuners", [["random"], ["genetic", "annealing"]])
def test_interleaved_campaign_equals_serial(tuners):
    camp = Campaign.grid(problems=["toy_quad"], tuners=tuners,
                         archs=("v5e", "v4"), seeds=range(2), budget=30,
                         workers=2)
    serial = camp.run()
    inter = camp.run(interleave=True)
    assert serial.keys() == inter.keys()
    for sid in serial:
        assert _traces_equal(serial[sid], inter[sid]), sid


def test_interleaved_campaign_share_archs_no_duplicate_evaluations():
    """A portability grid (same problem + seed across archs) must evaluate
    every (config, arch) pair at most once campaign-wide — sibling sessions
    read the shared columns instead of re-evaluating."""
    record = []
    prob = _record_problem(record)
    specs = Campaign.grid(problems=["camp_quad"], tuners=["random"],
                          archs=("v5e", "v4", "v5p"), seeds=(0, 1),
                          budget=25, workers=2).specs
    results = run_campaign(specs, problems={"camp_quad": prob}, workers=2)
    assert len(results) == 6
    assert len(record) == len(set(record)), "an evaluation ran twice"
    # same-seed random sessions ask identical rows on every arch: the
    # arch-shared sweep answers all three sessions from 25 unique configs
    per_arch = {}
    for cfg, arch in record:
        per_arch.setdefault(arch, set()).add(cfg)
    n_unique = len({cfg for cfg, _ in record})
    for arch, cfgs in per_arch.items():
        assert len(cfgs) <= n_unique

    # serial reference: identical traces, strictly more evaluations
    record2 = []
    prob2 = _record_problem(record2)
    for spec, (sid, res) in zip(specs, results.items()):
        ref = run_session(spec, problem=prob2)
        assert _traces_equal(ref, res)
    assert len(record2) > len(record)


def test_interleaved_campaign_resumes_partial_sessions(tmp_path):
    """Journaled prefixes from interrupted serial runs are replayed by the
    interleaved scheduler: nothing re-evaluated, traces unchanged."""
    record = []
    prob = _record_problem(record)
    store = SessionStore(tmp_path)
    specs = Campaign.grid(problems=["camp_quad"], tuners=["random"],
                          archs=("v5e", "v4"), seeds=(0,), budget=40,
                          workers=2).specs
    # interrupt the first session mid-way, serially
    run_session(specs[0], problem=prob, store=store, stop_after=10)
    n_before = len(record)
    results = run_campaign(specs, store, problems={"camp_quad": prob},
                           workers=2)
    # the journaled prefix was not re-evaluated
    phase2 = record[n_before:]
    assert not set(record[:n_before]) & set(phase2)
    uninterrupted = {s.session_id: run_session(s, problem=_record_problem([]))
                     for s in specs}
    for sid in results:
        assert _traces_equal(uninterrupted[sid], results[sid])
    for s in specs:
        assert store.meta(s.session_id)["status"] == "done"


def test_campaign_grid_interleave_with_store_is_replayable(tmp_path):
    store = SessionStore(tmp_path)
    camp = Campaign.grid(problems=["toy_rastrigin"], tuners=["random", "pso"],
                         archs=("v5e", "v4"), seeds=(3,), budget=24,
                         workers=2)
    first = camp.run(store, interleave=True)
    assert camp.done(store)
    again = camp.run(store, interleave=True)   # pure journal replay
    for sid in first:
        assert _traces_equal(first[sid], again[sid])


# --------------------------------------------------------------------- #
# exhaustive(limit=) compiled slice
# --------------------------------------------------------------------- #
def test_exhaustive_limit_matches_iterator():
    import itertools
    from repro.core.space import Constraint
    space = SearchSpace(
        [Param("a", tuple(range(6))), Param("b", tuple(range(5)))],
        [Constraint("sum", lambda c: (c["a"] + c["b"]) % 3 != 0)],
        name="lim")

    def fn(cfg, arch):
        return float(cfg["a"] * 5 + cfg["b"] + 1)

    prob = FunctionProblem(space, fn, name="lim")
    assert prob.space.compiled() is not None
    ref = list(itertools.islice(space.enumerate(constrained=True), 7))
    got = prob.exhaustive(limit=7)
    assert [t.config for t in got] == ref
    # and the sliced prefix agrees with the unlimited enumeration
    full = prob.exhaustive()
    assert [t.config for t in got] == [t.config for t in full[:7]]
    assert [t.objective for t in got] == [t.objective for t in full[:7]]
    # uncompiled fallback stays identical
    space2 = SearchSpace(
        [Param("a", tuple(range(6))), Param("b", tuple(range(5)))],
        [Constraint("sum", lambda c: (c["a"] + c["b"]) % 3 != 0)],
        name="lim2")
    space2.compiled = lambda *a, **k: None        # force the iterator path
    prob2 = FunctionProblem(space2, fn, name="lim2")
    got2 = prob2.exhaustive(limit=7)
    assert [t.config for t in got2] == ref
