"""Staticcheck conformance: every lint rule has a positive case (the
violation is caught) and a negative case (the compliant idiom passes),
the suppression/baseline machinery works, the shipped tree lints clean,
and the space auditor flags a deliberately pathological space while
passing every registered kernel space.
"""

from __future__ import annotations

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.core.space import Constraint, Param, SearchSpace
from repro.staticcheck import (Engine, apply_baseline, audit_space,
                               default_rules, load_baseline, write_baseline)

REPO_SRC = Path(__file__).resolve().parent.parent / "src"


def lint(path: str, code: str):
    """Lint one snippet as though it lived at ``path``."""
    eng = Engine(default_rules())
    return eng.lint_source(path, textwrap.dedent(code))


def rule_ids(findings):
    return [f.rule for f in findings]


# --------------------------------------------------------------------- #
# wall-clock
# --------------------------------------------------------------------- #

def test_wall_clock_flagged_in_deterministic_seam():
    fs = lint("repro/orchestrator/store.py", """
        import time
        def stamp():
            return time.time()
    """)
    assert rule_ids(fs) == ["wall-clock"]
    assert fs[0].line == 4


def test_wall_clock_default_arg_reference_ok():
    # referencing time.time as an injectable default is the sanctioned
    # pattern — only *calling* it inline is a violation
    fs = lint("repro/orchestrator/store.py", """
        import time
        class S:
            def __init__(self, clock=time.time):
                self._clock = clock
            def stamp(self):
                return self._clock()
    """)
    assert fs == []


def test_wall_clock_ignored_outside_seam():
    fs = lint("repro/orchestrator/doctor.py", """
        import time
        def stamp():
            return time.time()
    """)
    assert fs == []


# --------------------------------------------------------------------- #
# global-rng
# --------------------------------------------------------------------- #

def test_global_rng_flagged():
    fs = lint("repro/core/tuners/genetic.py", """
        import random
        import numpy as np
        def draw():
            return random.random() + np.random.rand()
    """)
    assert rule_ids(fs) == ["global-rng", "global-rng"]


def test_instance_rng_ok():
    fs = lint("repro/core/tuners/genetic.py", """
        import random
        import numpy as np
        def draw(seed):
            rng = random.Random(seed)
            g = np.random.default_rng(seed)
            return rng.random() + g.random()
    """)
    assert fs == []


# --------------------------------------------------------------------- #
# chaos-site
# --------------------------------------------------------------------- #

def test_unregistered_chaos_site_flagged():
    fs = lint("repro/orchestrator/workers.py", """
        from . import chaos
        def hook():
            chaos.fire("worker.crash.before_compleat")
    """)
    assert rule_ids(fs) == ["chaos-site"]
    assert "before_compleat" in fs[0].message


def test_registered_chaos_site_and_constant_ok():
    fs = lint("repro/orchestrator/workers.py", """
        from . import chaos
        def hook():
            chaos.fire("eval.hang")
            chaos.crash(chaos.WORKER_CRASH_BEFORE_COMPLETE)
    """)
    assert fs == []


# --------------------------------------------------------------------- #
# telemetry-name
# --------------------------------------------------------------------- #

def test_undocumented_span_flagged():
    fs = lint("repro/orchestrator/runner.py", """
        from ..telemetry.trace import span
        def work():
            with span("session.frobnicate", cat="session"):
                pass
    """)
    assert rule_ids(fs) == ["telemetry-name"]


def test_span_wrong_category_flagged():
    fs = lint("repro/orchestrator/runner.py", """
        from ..telemetry.trace import span
        def work():
            with span("journal.append", cat="broker"):
                pass
    """)
    assert rule_ids(fs) == ["telemetry-name"]
    assert "cat='store'" in fs[0].message


def test_documented_span_and_metric_ok():
    fs = lint("repro/orchestrator/runner.py", """
        from ..telemetry import metrics as _metrics
        from ..telemetry.trace import span
        def work():
            with span("session.ask", cat="session"):
                _metrics.counter("session.evals").inc()
    """)
    assert fs == []


def test_undocumented_metric_flagged():
    fs = lint("repro/orchestrator/runner.py", """
        from ..telemetry import metrics as _metrics
        def work():
            _metrics.counter("session.bogus_counter").inc()
    """)
    assert rule_ids(fs) == ["telemetry-name"]


# --------------------------------------------------------------------- #
# journal-keys
# --------------------------------------------------------------------- #

def test_undocumented_journal_key_flagged():
    fs = lint("repro/orchestrator/store.py", """
        def rec(key, t):
            return {"k": key, "o": t.objective, "v": t.valid, "z": 1}
    """)
    assert rule_ids(fs) == ["journal-keys"]
    assert "'z'" in fs[0].message


def test_documented_journal_record_ok():
    fs = lint("repro/orchestrator/store.py", """
        def rec(key, t, info):
            rec = {"k": key, "o": t.objective, "v": t.valid}
            if info:
                rec["i"] = info
            return rec
    """)
    assert fs == []


def test_non_journal_dicts_ignored():
    # single-char keys that share nothing with the record grammar, and
    # multi-char-key dicts, are not journal records
    fs = lint("repro/orchestrator/store.py", """
        def other():
            return ({"x": 1, "y": 2}, {"kind": "a", "other": "b"})
    """)
    assert fs == []


# --------------------------------------------------------------------- #
# model-store-keys
# --------------------------------------------------------------------- #

def test_undocumented_model_header_field_flagged():
    fs = lint("repro/core/surrogate/store.py", """
        def header(model):
            return {"magic": "repro-models", "version": 1,
                    "problem": model.problem, "extra_field": True}
    """)
    assert rule_ids(fs) == ["model-store-keys"]
    assert "extra_field" in fs[0].message


def test_documented_model_header_ok():
    fs = lint("repro/core/surrogate/store.py", """
        def header(model, checksum):
            return {"magic": "repro-models", "version": 1,
                    "problem": model.problem, "created_at": 0.0,
                    "feature_names": [], "archs": [], "params": {},
                    "n_rows": 0, "sections": {"model": checksum}}
    """)
    assert fs == []


def test_non_header_dicts_and_other_files_ignored():
    # dicts without a "magic" key are not headers; header-shaped dicts
    # outside the surrogate store module are someone else's schema
    fs = lint("repro/core/surrogate/store.py", """
        def other():
            return {"problem": "gemm", "anything": 1}
    """)
    assert fs == []
    fs = lint("repro/servedb/snapshot.py", """
        def header():
            return {"magic": "other-format", "custom": 1}
    """)
    assert fs == []


# --------------------------------------------------------------------- #
# lookup-raise
# --------------------------------------------------------------------- #

def test_raise_in_public_lookup_flagged():
    fs = lint("repro/servedb/lookup.py", """
        class ServeDB:
            def lookup(self, kernel):
                if kernel is None:
                    raise ValueError("no kernel")
    """)
    assert rule_ids(fs) == ["lookup-raise"]
    assert "lookup" in fs[0].message


def test_raise_in_private_helper_ok():
    fs = lint("repro/servedb/lookup.py", """
        class ServeDB:
            def _check(self, kernel):
                raise ValueError("internal")
    """)
    assert fs == []


def test_raise_elsewhere_ok():
    fs = lint("repro/servedb/snapshot.py", """
        def publish(snap):
            raise IOError("disk full")
    """)
    assert fs == []


# --------------------------------------------------------------------- #
# broker-tx
# --------------------------------------------------------------------- #

def test_mutation_outside_tx_flagged():
    fs = lint("repro/orchestrator/broker.py", """
        class SQLiteBroker:
            def submit(self, payload):
                self._conn().execute(
                    "INSERT INTO jobs (payload) VALUES (?)", (payload,))
    """)
    assert rule_ids(fs) == ["broker-tx"]
    assert "INSERT" in fs[0].message


def test_mutation_inside_tx_ok():
    fs = lint("repro/orchestrator/broker.py", """
        class SQLiteBroker:
            def submit(self, payload):
                with self._tx() as cur:
                    cur.execute(
                        "INSERT INTO jobs (payload) VALUES (?)", (payload,))
            def _reap_cur(self, cur):
                cur.execute("UPDATE jobs SET state=? WHERE id=?", (1, 2))
            def counts(self):
                return self._conn().execute(
                    "SELECT state, COUNT(*) FROM jobs").fetchall()
    """)
    assert fs == []


# --------------------------------------------------------------------- #
# retry-sleep
# --------------------------------------------------------------------- #

def test_sleep_in_except_handler_flagged():
    fs = lint("repro/orchestrator/anything.py", """
        import time
        def fetch(conn):
            for attempt in range(5):
                try:
                    return conn.get()
                except OSError:
                    time.sleep(2 ** attempt)
    """)
    assert rule_ids(fs) == ["retry-sleep"]


def test_idle_polling_sleep_ok():
    fs = lint("repro/orchestrator/anything.py", """
        import time
        def poll(queue):
            while queue.empty():
                time.sleep(0.5)
    """)
    assert fs == []


# --------------------------------------------------------------------- #
# engine machinery: suppressions, baselines, parse errors
# --------------------------------------------------------------------- #

def test_same_line_suppression():
    fs = lint("repro/orchestrator/store.py", """
        import time
        def stamp():
            return time.time()  # repro-lint: disable=wall-clock
    """)
    assert fs == []


def test_comment_line_suppresses_next_line():
    fs = lint("repro/orchestrator/store.py", """
        import time
        def stamp():
            # repro-lint: disable=wall-clock
            return time.time()
    """)
    assert fs == []


def test_suppression_is_rule_specific():
    fs = lint("repro/orchestrator/store.py", """
        import time
        def stamp():
            return time.time()  # repro-lint: disable=global-rng
    """)
    assert rule_ids(fs) == ["wall-clock"]


def test_baseline_roundtrip(tmp_path):
    code = """
        import time
        def stamp():
            return time.time()
    """
    findings = lint("repro/orchestrator/store.py", code)
    assert findings
    bl = tmp_path / "baseline.json"
    write_baseline(bl, findings)
    assert apply_baseline(findings, load_baseline(bl)) == []
    # a new, different finding is NOT excused by the old baseline
    fresh = lint("repro/orchestrator/store.py", """
        import random
        def draw():
            return random.random()
    """)
    assert rule_ids(apply_baseline(fresh, load_baseline(bl))) == ["global-rng"]


def test_baseline_key_survives_line_shifts():
    a = lint("repro/orchestrator/store.py", """
        import time
        def stamp():
            return time.time()
    """)
    b = lint("repro/orchestrator/store.py", """
        import time
        # an unrelated comment pushing the violation down
        def stamp():
            return time.time()
    """)
    assert a[0].line != b[0].line
    assert a[0].baseline_key == b[0].baseline_key


def test_syntax_error_is_a_finding_not_a_crash():
    fs = lint("repro/orchestrator/store.py", "def broken(:\n")
    assert rule_ids(fs) == ["parse-error"]


def test_shipped_tree_lints_clean():
    eng = Engine(default_rules(), root=REPO_SRC)
    findings = eng.lint_paths([REPO_SRC / "repro"])
    assert findings == [], "\n".join(f.render() for f in findings)


# --------------------------------------------------------------------- #
# space auditor
# --------------------------------------------------------------------- #

def _pathological_space() -> SearchSpace:
    """Two 0..4 params constrained to two opposite corners: value 2 of
    each param is dead, and the two corners are Hamming-1 disconnected."""
    return SearchSpace(
        [Param("x", tuple(range(5))), Param("y", tuple(range(5)))],
        [Constraint(
            "corners",
            lambda c: (c["x"] <= 1 and c["y"] <= 1)
            or (c["x"] >= 3 and c["y"] >= 3),
            vec=lambda cols: ((cols["x"] <= 1) & (cols["y"] <= 1))
            | ((cols["x"] >= 3) & (cols["y"] >= 3)))],
        name="pathological")


def test_audit_flags_pathological_space():
    rep = audit_space(_pathological_space())
    assert not rep.ok
    checks = {f.check for f in rep.findings}
    assert "dead-value" in checks
    assert "disconnected" in checks
    assert rep.n_components == 2
    assert rep.n_valid == 8
    dead = [f for f in rep.findings if f.check == "dead-value"]
    assert len(dead) == 2            # both x and y have value 2 dead


def test_audit_unsatisfiable_space():
    sp = SearchSpace(
        [Param("x", (0, 1))],
        [Constraint("never", lambda c: False,
                    vec=lambda cols: cols["x"] < 0)],
        name="empty")
    rep = audit_space(sp)
    assert not rep.ok
    assert [f.check for f in rep.findings] == ["unsatisfiable"]
    assert rep.findings[0].severity == "error"


def test_audit_redundant_constraint_is_info_only():
    # x<=y keeps every value of both params alive (x=v pairs with y=3,
    # y=v pairs with x=0) and the staircase stays Hamming-1 connected,
    # so the only finding is the implied x<=y+1
    sp = SearchSpace(
        [Param("x", tuple(range(4))), Param("y", tuple(range(4)))],
        [Constraint("x_le_y", lambda c: c["x"] <= c["y"],
                    vec=lambda cols: cols["x"] <= cols["y"]),
         Constraint("x_le_y1", lambda c: c["x"] <= c["y"] + 1,  # implied
                    vec=lambda cols: cols["x"] <= cols["y"] + 1)],
        name="redundant")
    rep = audit_space(sp)
    red = [f for f in rep.findings if f.check == "redundant-constraint"]
    assert len(red) == 1 and "x_le_y1" in red[0].message
    assert red[0].severity == "info"
    assert rep.ok                     # hygiene, not a failure


def test_audit_clean_space_ok():
    sp = SearchSpace([Param("x", (0, 1, 2))], name="clean")
    rep = audit_space(sp)
    assert rep.ok and rep.findings == [] and rep.n_components == 1


@pytest.mark.parametrize("name", [
    "gemm", "conv2d", "pnpoly", "nbody", "hotspot", "dedisp", "expdist",
    "attention", "toy_quad", "toy_rastrigin"])
def test_all_shipped_spaces_pass_audit(name):
    from repro.orchestrator.registry import make_problem
    rep = audit_space(make_problem(name).space)
    bad = [f.render() for f in rep.findings if f.severity != "info"]
    assert rep.ok, f"{name}: " + "; ".join(bad)
    assert rep.n_components == 1, \
        f"{name}: valid region disconnected ({rep.n_components} components)"


# --------------------------------------------------------------------- #
# CLI surface
# --------------------------------------------------------------------- #

def _run_cli(*argv):
    import os
    env = dict(os.environ, PYTHONPATH=str(REPO_SRC))
    return subprocess.run(
        [sys.executable, "-m", "repro.orchestrator", *argv],
        capture_output=True, text=True, env=env)


def test_cli_lint_strict_clean_tree_exits_zero():
    r = _run_cli("lint", "--strict", str(REPO_SRC / "repro"))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 finding(s)" in r.stdout


def test_cli_lint_strict_fails_on_violation(tmp_path):
    bad = tmp_path / "repro" / "orchestrator" / "store.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import time\n\ndef stamp():\n    return time.time()\n")
    r = _run_cli("lint", "--strict", str(bad))
    assert r.returncode == 1
    assert "wall-clock" in r.stdout
    # advisory mode reports but exits 0
    r = _run_cli("lint", str(bad))
    assert r.returncode == 0 and "wall-clock" in r.stdout
    # a written baseline excuses it for strict mode
    bl = tmp_path / "baseline.json"
    r = _run_cli("lint", "--write-baseline", str(bl), str(bad))
    assert r.returncode == 0 and bl.exists()
    r = _run_cli("lint", "--strict", "--baseline", str(bl), str(bad))
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_lint_json_output():
    r = _run_cli("lint", "--json", str(REPO_SRC / "repro" / "staticcheck"))
    assert r.returncode == 0
    rec = json.loads(r.stdout)
    assert rec["ok"] is True and rec["findings"] == []


def test_doctor_lint_flag(tmp_path):
    from repro.orchestrator.doctor import diagnose
    from repro.orchestrator.store import SessionStore
    store = SessionStore(tmp_path / "sessions")
    report = diagnose(store, lint=True)
    assert report["lint"] == {"findings": []}
    assert report["ok"]
