"""Per-arch smoke tests: reduced config, one forward/train step + one decode
step on CPU; shape and finiteness assertions (deliverable f)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduce_config
from repro.models import build_model

ARCH_NAMES = list(ARCHS)


def _batch(cfg, key, b=2, t=16):
    kt, kl, kf = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(kt, (b, t), 0, cfg.vocab),
             "labels": jax.random.randint(kl, (b, t), 0, cfg.vocab)}
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(kf, (b, t, cfg.d_model),
                                            jnp.float32)
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(kf, (b, cfg.n_patches,
                                                  cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_loss(arch):
    cfg = reduce_config(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    logits, aux, _ = model.forward(params, batch)
    assert logits.shape == (*batch["tokens"].shape, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = model.train_loss(params, batch)
    assert bool(jnp.isfinite(loss))
    assert float(metrics["nll"]) >= 0.0


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_train_step_moves_loss(arch):
    """One SGD step on the reduced config must change (usually reduce) the
    loss and produce finite grads."""
    cfg = reduce_config(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))

    def loss_fn(p):
        return model.train_loss(p, batch)[0]

    loss0, grads = jax.value_and_grad(loss_fn)(params)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))
    lr = 0.05
    params2 = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32))
        .astype(p.dtype), params, grads)
    loss1 = loss_fn(params2)
    assert bool(jnp.isfinite(loss1))
    assert float(loss1) != float(loss0)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_decode_step(arch):
    cfg = reduce_config(ARCHS[arch])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b = 2
    batch = _batch(cfg, jax.random.key(1), b=b)
    cache = model.init_cache(b, max_len=32)
    enc_out = None
    if cfg.n_enc_layers:
        enc_out = model._encode(params, batch["frames"])
    tok = batch["tokens"][:, :1]
    for pos in range(3):
        logits, cache = model.decode_step(params, cache, tok, pos,
                                          enc_out=enc_out)
        assert logits.shape == (b, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, axis=-1)[:, None]


def test_param_counts_match_names():
    """Analytic parameter counts must land near the branded sizes."""
    expected = {
        "qwen3-8b": (7.0, 9.0),
        "qwen3-14b": (13.0, 16.0),
        "deepseek-coder-33b": (30.0, 36.0),
        "gemma3-27b": (24.0, 30.0),
        "deepseek-v2-236b": (225.0, 245.0),
        "recurrentgemma-9b": (8.0, 11.0),
        "granite-moe-3b-a800m": (2.5, 4.0),
        "rwkv6-1.6b": (1.4, 2.1),
        "whisper-medium": (0.6, 1.0),
        "internvl2-26b": (17.0, 26.0),   # LM backbone (ViT is stubbed)
    }
    for name, (lo, hi) in expected.items():
        n = ARCHS[name].param_count() / 1e9
        assert lo <= n <= hi, f"{name}: {n:.2f}B not in [{lo},{hi}]"


def test_moe_active_params():
    cfg = ARCHS["deepseek-v2-236b"]
    active = cfg.active_param_count() / 1e9
    assert 15.0 <= active <= 25.0      # paper: ~21B activated


def test_gqa_decode_matches_forward():
    """Prefill-then-compare: decoding token t with a cache must reproduce the
    full-sequence forward logits at position t."""
    cfg = reduce_config(ARCHS["qwen3-8b"])
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, t = 1, 8
    toks = jax.random.randint(jax.random.key(2), (b, t), 0, cfg.vocab)
    logits_full, _, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(b, max_len=t)
    outs = []
    for pos in range(t):
        lg, cache = model.decode_step(params, cache, toks[:, pos:pos + 1],
                                      pos)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    diff = jnp.max(jnp.abs(dec - logits_full))
    scale = jnp.max(jnp.abs(logits_full)) + 1e-6
    assert float(diff / scale) < 0.05, float(diff / scale)
