"""Checkpoint fault-tolerance contract: atomic commit, integrity, retention,
auto-resume, and structure checks."""

import zlib
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"w": jax.random.normal(k, (8, 16), jnp.float32),
            "b": jnp.arange(16, dtype=jnp.bfloat16),
            "nested": {"m": jnp.full((4,), 3, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 7, t, extra={"step": 7, "note": "x"})
    like = jax.eval_shape(lambda: t)
    got, extra = ckpt.restore(tmp_path, like)
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_and_retention(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(tmp_path, s, t, keep=2)
    assert ckpt.latest_step(tmp_path) == 5
    kept = sorted(p.name for p in Path(tmp_path).glob("step_*"))
    assert len(kept) == 2 and kept[-1].endswith("000000005")


def test_crash_mid_save_leaves_previous_intact(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 1, t)
    # simulate a crash: stale tmp dir from a dead writer
    tmp_dir = Path(tmp_path) / "step_000000002.tmp"
    tmp_dir.mkdir()
    (tmp_dir / "junk").write_bytes(b"partial")
    assert ckpt.latest_step(tmp_path) == 1
    got, _ = ckpt.restore(tmp_path, jax.eval_shape(lambda: t))
    assert got is not None
    ckpt.save(tmp_path, 3, t)                    # sweeps the tmp litter
    assert not tmp_dir.exists()


def test_corrupt_shard_fails_loudly(tmp_path):
    t = _tree()
    d = ckpt.save(tmp_path, 1, t)
    shard = d / "shard_00000.bin.zst"
    raw = bytearray(shard.read_bytes())
    raw[len(raw) // 2] ^= 0xFF
    shard.write_bytes(bytes(raw))
    with pytest.raises((IOError, zlib.error, Exception)):
        ckpt.restore(tmp_path, jax.eval_shape(lambda: t))


def test_structure_mismatch_rejected(tmp_path):
    ckpt.save(tmp_path, 1, _tree())
    wrong = {"only": jnp.zeros((3,))}
    with pytest.raises(ValueError, match="leaves"):
        ckpt.restore(tmp_path, jax.eval_shape(lambda: wrong))


def test_elastic_restore_with_shardings(tmp_path):
    """Restore placing leaves with explicit (different-mesh) shardings."""
    t = _tree()
    ckpt.save(tmp_path, 1, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.NamedSharding(mesh, jax.sharding.PartitionSpec()), t)
    got, _ = ckpt.restore(tmp_path, jax.eval_shape(lambda: t), shardings=sh)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_falls_back_when_pointer_stale(tmp_path):
    t = _tree()
    ckpt.save(tmp_path, 1, t)
    ckpt.save(tmp_path, 2, t)
    (Path(tmp_path) / "LATEST").write_text("99")     # stale pointer
    assert ckpt.latest_step(tmp_path) == 2


def test_train_loop_auto_resume(tmp_path):
    """A restarted loop continues from the checkpointed step (the whole
    node-failure recovery story, end to end on a reduced model)."""
    from repro.configs import ARCHS, reduce_config
    from repro.data import DataConfig
    from repro.launch.mesh import make_host_mesh
    from repro.train.train_loop import TrainLoop, TrainLoopConfig

    cfg = reduce_config(ARCHS["qwen3-8b"])
    mesh = make_host_mesh(model=1)
    mk = lambda steps: TrainLoop(
        cfg, mesh,
        loop_cfg=TrainLoopConfig(total_steps=steps, log_every=100,
                                 ckpt_every=2, ckpt_dir=str(tmp_path),
                                 auto_resume=True),
        data_cfg=DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2))
    s1 = mk(4).run()
    assert s1.step == 4
    loop2 = mk(6)
    s2 = loop2.run()
    assert s2.step == 6
    assert any(e["event"] == "resumed" and e["step"] == 4
               for e in loop2.events)
