"""Per-kernel correctness: Pallas (interpret=True) vs pure-jnp oracle, swept
over sampled configs from each kernel's own search space + shape variants.

interpret mode executes the kernel body on CPU — the same BlockSpec/grid
program that runs on TPU — so this validates indexing, accumulation and
masking logic for every tunable parameter combination sampled.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention.space import AttentionProblem
from repro.kernels.conv2d.space import Conv2dProblem
from repro.kernels.dedisp.space import DedispProblem
from repro.kernels.expdist.space import ExpdistProblem
from repro.kernels.hotspot.space import HotspotProblem
from repro.kernels.matmul.space import GemmProblem
from repro.kernels.nbody.space import NbodyProblem
from repro.kernels.pnpoly.space import PnpolyProblem

PROBLEMS = {
    "gemm": GemmProblem,
    "conv2d": Conv2dProblem,
    "nbody": NbodyProblem,
    "hotspot": HotspotProblem,
    "pnpoly": PnpolyProblem,
    "expdist": ExpdistProblem,
    "dedisp": DedispProblem,
    "attention": AttentionProblem,
}

N_CONFIGS = 4          # sampled tunable configs per kernel

#: relative-L2 tolerance: (full-precision configs, low-precision configs).
#: bf16 accumulate/compute configs lose ~8 mantissa bits; the oracle runs in
#: f32, so the config-dependent budget is part of the contract under test.
TOLS = {
    "gemm": (5e-3, 2e-2),
    "conv2d": (5e-3, 3e-2),
    "nbody": (1e-3, 8e-2),      # 1/r^3 amplifies bf16 rounding near pairs
    "hotspot": (5e-3, 3e-2),
    "pnpoly": (0.0, 0.0),       # integer output: exact
    "expdist": (1e-3, 2e-2),
    "dedisp": (1e-3, 2e-2),
    "attention": (5e-3, 2e-2),
}


def _is_lowprec(config) -> bool:
    return any(v == "bf16" for v in config.values())


def _check(name, prob, config, key):
    inputs = prob.make_inputs(key, small=True)
    want = prob.run_reference(config, inputs)
    got = prob.run_kernel(config, inputs, interpret=True)
    w = np.asarray(want, dtype=np.float64)
    g = np.asarray(got, dtype=np.float64)
    assert g.shape == w.shape, (g.shape, w.shape)
    tol = TOLS[name][1 if _is_lowprec(config) else 0]
    err = np.linalg.norm(g - w) / max(np.linalg.norm(w), 1e-12)
    assert err <= tol + 1e-12, f"{name} {config}: rel_l2={err:.4g}"


@pytest.mark.parametrize("name", list(PROBLEMS))
def test_kernel_matches_oracle_across_configs(name):
    prob = PROBLEMS[name]()
    cfgs = prob.space.sample_distinct(N_CONFIGS, seed=42)
    # always include the deployment default where it is valid
    for i, cfg in enumerate(cfgs):
        _check(name, prob, cfg, jax.random.key(100 + i))


@pytest.mark.parametrize("name", ["gemm", "attention", "conv2d"])
def test_kernel_dtype_sweep(name):
    """Shape/dtype sweep for the LM-stack kernels (deliverable c)."""
    prob = PROBLEMS[name]()
    cfg = prob.space.sample_distinct(1, seed=7)[0]
    for i, dtype in enumerate((jnp.float32, jnp.bfloat16)):
        prob.dtype = dtype
        _check(name, prob, cfg, jax.random.key(i))


def test_gemm_shape_sweep():
    prob = GemmProblem()
    cfg = {"block_m": 64, "block_n": 128, "block_k": 128, "unroll_k": 1,
           "grid_order": "mn", "split_k": 1, "acc_dtype": "f32",
           "rhs_layout": "kn"}
    for m, n, k in ((128, 128, 128), (256, 128, 512), (128, 256, 256)):
        a = jax.random.normal(jax.random.key(0), (m, k), jnp.bfloat16)
        b = jax.random.normal(jax.random.key(1), (k, n), jnp.bfloat16)
        c = jax.random.normal(jax.random.key(2), (m, n), jnp.bfloat16)
        from repro.kernels.matmul.kernel import gemm
        from repro.kernels.matmul.ref import gemm_reference
        got = gemm(a, b, c, alpha=1.0, beta=1.0, interpret=True, **cfg)
        want = gemm_reference(a, b, c, 1.0, 1.0)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=3e-2, atol=3e-2)


def test_attention_causal_and_full():
    prob = AttentionProblem()
    cfg = {"block_q": 64, "block_kv": 128}
    from repro.kernels.attention.kernel import flash_attention
    from repro.kernels.attention.ref import mha_reference
    q = jax.random.normal(jax.random.key(0), (4, 128, 64), jnp.float32)
    k = jax.random.normal(jax.random.key(1), (2, 256, 64), jnp.float32)
    v = jax.random.normal(jax.random.key(2), (2, 256, 64), jnp.float32)
    for causal in (False, True):
        got = flash_attention(q, k, v, causal=causal, interpret=True, **cfg)
        want = mha_reference(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)


def test_ops_dispatch_uses_reference_on_cpu():
    """ops wrappers fall back to the XLA reference on non-TPU backends."""
    from repro.kernels.matmul.ops import gemm as gemm_op
    from repro.kernels.matmul.ref import gemm_reference
    a = jax.random.normal(jax.random.key(0), (64, 64), jnp.float32)
    b = jax.random.normal(jax.random.key(1), (64, 64), jnp.float32)
    c = jnp.zeros((64, 64), jnp.float32)
    np.testing.assert_allclose(np.asarray(gemm_op(a, b, c)),
                               np.asarray(gemm_reference(a, b, c, 1.0, 1.0)),
                               rtol=1e-5)


def test_invalid_configs_evaluate_to_inf():
    """Constraint-violating configs are invalid trials (the suite's analogue
    of a CUDA compile failure), never exceptions."""
    import math
    prob = GemmProblem()
    cfg = dict(prob.space.sample_distinct(1, seed=0)[0])
    cfg["block_m"] = 512
    cfg["block_k"] = 1024
    cfg["acc_dtype"] = "f32"
    cfg["block_n"] = 512
    t = prob.evaluate(cfg)          # VMEM constraint must trip
    if not prob.space.satisfies(cfg):
        assert not t.valid and math.isinf(t.objective)


# ------------------------------------------------------------------ #
# index-native evaluation: columnar features == scalar features
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def _problems():
    return {name: cls() for name, cls in PROBLEMS.items()}


@pytest.mark.parametrize("name", sorted(PROBLEMS))
def test_feature_columns_bitwise_equal_scalar(name, _problems):
    """Every kernel's vectorized ``feature_columns`` must reproduce the
    per-config ``features`` path bit for bit — columns, and therefore
    cost-model objectives, on every architecture."""
    from repro.core.costmodel import (ARCH_NAMES, FeatureBatch,
                                      estimate_seconds_batch)
    prob = _problems[name]
    comp = prob.space.compiled()
    assert comp is not None
    rows = comp.sample_rows_distinct(200, __import__("random").Random(3))
    cols = comp.value_columns(rows)
    cfgs = comp.decode_many(rows)
    for arch in ARCH_NAMES:
        fb = prob.feature_columns(cols, arch)
        assert fb is not None
        ref = FeatureBatch.from_features(
            [prob.features(c, arch) for c in cfgs])
        for field in FeatureBatch.FIELDS:
            got = np.broadcast_to(np.asarray(getattr(fb, field)), (len(rows),))
            assert np.array_equal(got, getattr(ref, field)), (arch, field)
        assert np.array_equal(
            np.broadcast_to(np.asarray(estimate_seconds_batch(fb, arch)),
                            (len(rows),)),
            estimate_seconds_batch(ref, arch)), arch


@pytest.mark.parametrize("name", sorted(PROBLEMS))
def test_vec_constraints_match_predicates(name, _problems):
    """All suite constraints carry vectorized forms that agree with their
    Python predicates over the whole cross product (the compiled mask is
    exactly the predicate-chain acceptance set)."""
    from repro.core.spacetable import CompiledSpace
    sp = _problems[name].space
    assert all(c.vec is not None for c in sp.constraints), name
    comp = sp.compiled()
    codes = CompiledSpace.codes_for(sp)
    names = sp.param_names
    pyvals = [p.values for p in sp.params]
    # spot-check a deterministic slice of rows (full sweep is the
    # spacetable property tests' job on random spaces)
    rows = np.unique(np.linspace(0, sp.cardinality - 1, 500, dtype=np.int64))
    for r in rows:
        cfg = {nm: pv[j] for nm, pv, j in zip(names, pyvals, codes[r])}
        assert bool(comp.mask[r]) == all(c.fn(cfg) for c in sp.constraints)


@pytest.mark.parametrize("name", sorted(PROBLEMS))
def test_rows_endpoints_match_evaluate_many(name, _problems):
    """``trials_for_rows`` / ``objectives_for_rows`` /
    ``objectives_for_rows_archs`` agree exactly with ``evaluate_many`` —
    including the small-batch scalar fallback (below the columnar
    threshold) and the shared-columns multi-arch sweep."""
    import random as _random

    from repro.core.costmodel import ARCH_NAMES
    prob = _problems[name]
    comp = prob.space.compiled()
    for n in (1, 3, 64):            # below and above the columnar threshold
        rows = comp.sample_rows_distinct(n, _random.Random(n))
        cfgs = comp.decode_many(rows)
        for arch in ("v4", "v6e"):
            want = [t.objective for t in prob.evaluate_many(cfgs, arch)]
            got_t = prob.trials_for_rows(rows, arch)
            assert [t.objective for t in got_t] == want
            assert [t.config for t in got_t] == cfgs
            assert prob.objectives_for_rows(rows, arch).tolist() == want
        multi = prob.objectives_for_rows_archs(rows, ARCH_NAMES)
        for i, arch in enumerate(ARCH_NAMES):
            assert multi[i].tolist() == \
                [t.objective for t in prob.evaluate_many(cfgs, arch)]
