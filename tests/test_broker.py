"""Queue-backend conformance suite + broker campaign properties.

Every protocol property runs against BOTH backends (``MemoryBroker`` and
``SQLiteBroker``) through one parametrized fixture: identical traces to
the serial loop, lease-expiry requeue, poison-result isolation,
concurrent-worker dedup, attempts-cap failure, async-tell resume.  The
multi-*process* properties (detached workers, kill one mid-campaign) run
against the SQLite backend with real subprocesses at the bottom.
"""

from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import threading
import time
from contextlib import contextmanager
from pathlib import Path

import pytest

from repro.core.problem import FunctionProblem
from repro.core.space import Param, SearchSpace
from repro.orchestrator import (BrokerWorker, Campaign, MemoryBroker,
                                SessionSpec, SessionStore, SQLiteBroker,
                                run_campaign, run_session)
from repro.orchestrator import registry
from repro.orchestrator.cli import _parse_tuner_args, main as cli_main
from repro.orchestrator.queue import LEASED, PENDING
from repro.orchestrator.session import CAMPAIGN_TUNER_DEFAULTS

ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture(params=["memory", "sqlite"])
def broker(request, tmp_path):
    b = (MemoryBroker() if request.param == "memory"
         else SQLiteBroker(tmp_path / "queue.db"))
    yield b
    b.close()


@contextmanager
def _fleet(broker, n=2, lease_s=5.0, workers=2):
    """n BrokerWorker loops as daemon threads, stopped on exit."""
    stop = threading.Event()
    members = [BrokerWorker(broker, workers=workers, lease_s=lease_s,
                            poll_s=0.005) for _ in range(n)]
    threads = [threading.Thread(target=w.run, kwargs={"stop": stop},
                                daemon=True) for w in members]
    for t in threads:
        t.start()
    try:
        yield members
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=30)


def _traces_equal(a, b) -> bool:
    return ([t.objective for t in a.trials] == [t.objective for t in b.trials]
            and [t.config for t in a.trials] == [t.config for t in b.trials]
            and [t.valid for t in a.trials] == [t.valid for t in b.trials])


def _poison_problem():
    space = SearchSpace([Param("a", tuple(range(24)))], name="toy_poison")

    def fn(cfg, arch):
        if cfg["a"] % 5 == 2:
            raise RuntimeError(f"kaboom {cfg['a']}")
        return float(cfg["a"] + 1)

    return FunctionProblem(space, fn, name="toy_poison")


# --------------------------------------------------------------------- #
# conformance: identical traces
# --------------------------------------------------------------------- #
def test_broker_campaign_bitidentical_to_serial(broker, tmp_path):
    """The acceptance property: a campaign through the durable queue —
    journals, published tables, and returned traces — equals serial
    ``run_session``, with async tell and cross-session row sharing."""
    camp = Campaign.grid(problems=["toy_rastrigin"],
                         tuners=["random", "genetic"],
                         archs=["v5e", "v4"], seeds=range(2), budget=40,
                         workers=2)
    store_ref = SessionStore(tmp_path / "ref")
    ref = {s.session_id: run_session(s, store=store_ref)
           for s in camp.specs}

    store_brk = SessionStore(tmp_path / "brk")
    with _fleet(broker, n=3):
        res = run_campaign(camp.specs, store_brk, broker=broker)

    assert res.keys() == ref.keys()
    for sid in ref:
        assert _traces_equal(ref[sid], res[sid]), sid
        # journal files are byte-identical (same records, same order)
        assert (store_ref._journal_path(sid).read_text()
                == store_brk._journal_path(sid).read_text()), sid
        # published ResultTables agree
        ta = store_ref.tables.get("toy_rastrigin", ref[sid].arch,
                                  f"session_{sid}")
        tb = store_brk.tables.get("toy_rastrigin", ref[sid].arch,
                                  f"session_{sid}")
        assert ta.configs == tb.configs and ta.objectives == tb.objectives
        assert store_brk.meta(sid)["status"] == "done"


def test_run_session_broker_form(broker):
    spec = SessionSpec(problem="toy_quad", tuner="genetic", budget=30,
                       seed=5)
    ref = run_session(spec)
    with _fleet(broker, n=1):
        res = run_session(spec, broker=broker)
    assert _traces_equal(ref, res)


def test_broker_poison_result_isolation(broker, monkeypatch):
    """A config that raises inside a worker comes back as an invalid
    poisoned trial — fault markers identical to in-process evaluation —
    and never fails the job or wedges the campaign."""
    monkeypatch.setitem(registry.TOY_FACTORIES, "toy_poison",
                        _poison_problem)
    spec = SessionSpec(problem="toy_poison", tuner="random", budget=20,
                       seed=3)
    ref = run_session(spec)
    with _fleet(broker, n=2):
        res = run_campaign([spec], broker=broker)[spec.session_id]
    assert _traces_equal(ref, res)
    poisoned = [t for t in res.trials if t.info.get("poison")]
    assert poisoned, "grid must hit at least one raising config"
    for t_ref, t_brk in zip(ref.trials, res.trials):
        assert t_ref.info.get("poison") == t_brk.info.get("poison")
        assert t_ref.info.get("error") == t_brk.info.get("error")
        assert t_ref.info.get("attempts") == t_brk.info.get("attempts")


# --------------------------------------------------------------------- #
# conformance: lease protocol
# --------------------------------------------------------------------- #
def test_lease_expiry_requeue_and_completion_dedup(broker):
    """A worker that stops heartbeating loses its lease; the requeued job
    goes to the next worker, and the dead worker's late result is
    rejected — two workers can never both publish one job."""
    jid = broker.submit({"problem": "toy_quad", "archs": ["v5e"],
                         "rows": [1], "sessions": []})
    got = broker.lease("w-dead", lease_s=0.05)
    assert got is not None and got[0] == jid
    assert broker.lease("w-live", lease_s=0.05) is None   # still leased
    time.sleep(0.1)                                       # lease expires
    got2 = broker.lease("w-live", lease_s=30.0)
    assert got2 is not None and got2[0] == jid            # requeued
    # the presumed-dead worker wakes up: every write is rejected
    assert not broker.complete(jid, "w-dead", {"arch_trials": {}})
    assert not broker.fail(jid, "w-dead", "late")
    assert not broker.heartbeat(jid, "w-dead", 30.0)
    # the live holder's result lands, exactly once
    assert broker.complete(jid, "w-live", {"arch_trials": {"v5e": []}})
    done, failed = broker.collect()
    assert list(done) == [jid] and not failed
    done, failed = broker.collect()                       # pop-once
    assert not done and not failed


def test_heartbeat_keeps_long_job_alive(broker):
    jid = broker.submit({"problem": "toy_quad", "archs": ["v5e"],
                         "rows": [1], "sessions": []})
    assert broker.lease("w1", lease_s=0.1)[0] == jid
    for _ in range(6):                 # work "runs" 3x the lease window
        time.sleep(0.05)
        assert broker.heartbeat(jid, "w1", 0.1)
    assert broker.reap() == 0
    assert broker.lease("w2", lease_s=0.1) is None
    assert broker.complete(jid, "w1", {"arch_trials": {"v5e": []}})


def test_attempts_cap_turns_expiry_into_failure(broker):
    jid = broker.submit({"problem": "toy_quad", "archs": ["v5e"],
                         "rows": [1], "sessions": ["sid-x"]})
    for i in range(broker.max_attempts):
        got = broker.lease(f"w{i}", lease_s=0.02)
        assert got is not None and got[0] == jid
        time.sleep(0.05)
    assert broker.lease("w-final", lease_s=0.02) is None  # failed, not pending
    done, failed = broker.collect()
    assert not done and len(failed) == 1
    assert failed[0]["id"] == jid
    assert failed[0]["attempts"] == broker.max_attempts
    assert "presumed dead" in failed[0]["error"]


def test_concurrent_workers_each_job_leased_once(broker):
    """Many threads hammering ``lease`` never co-own a job (the
    conformance form of MITuna's claim-row-for-update)."""
    n_jobs = 24
    for i in range(n_jobs):
        broker.submit({"problem": "toy_quad", "archs": ["v5e"],
                       "rows": [i], "sessions": []})
    claimed: list[tuple[int, str]] = []
    lock = threading.Lock()

    def hammer(wid: str) -> None:
        while True:
            got = broker.lease(wid, lease_s=30.0)
            if got is None:
                return
            with lock:
                claimed.append((got[0], wid))
            broker.complete(got[0], wid, {"arch_trials": {"v5e": []}})

    threads = [threading.Thread(target=hammer, args=(f"w{i}",))
               for i in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    jobs = [j for j, _ in claimed]
    assert sorted(jobs) == sorted(set(jobs)) and len(jobs) == n_jobs
    done, failed = broker.collect()
    assert len(done) == n_jobs and not failed


def test_counts_and_in_flight_views(broker):
    jid = broker.submit({"problem": "toy_quad", "archs": ["v5e"],
                         "rows": [1], "sessions": ["sid-a", "sid-b"]})
    assert broker.counts()[PENDING] == 1
    broker.lease("w9", lease_s=30.0)
    assert broker.counts()[LEASED] == 1
    flight = broker.in_flight()
    assert len(flight) == 1
    assert flight[0]["job"] == jid and flight[0]["worker"] == "w9"
    assert flight[0]["heartbeat_age"] >= 0.0
    assert sorted(flight[0]["sessions"]) == ["sid-a", "sid-b"]


# --------------------------------------------------------------------- #
# async-tell campaign behavior
# --------------------------------------------------------------------- #
def test_failed_job_marks_sessions_failed_journal_intact(broker, tmp_path,
                                                         monkeypatch):
    """Attempts-cap exhaustion surfaces as the same failure shape as an
    in-process evaluation error: campaign raises, sessions are FAILED in
    the store with their journals intact (hence resumable)."""
    broker.max_attempts = 1
    monkeypatch.setattr(BrokerWorker, "_evaluate",
                        lambda self, payload: (_ for _ in ()).throw(
                            RuntimeError("worker exploded")))
    store = SessionStore(tmp_path / "store")
    spec = SessionSpec(problem="toy_quad", tuner="random", budget=20, seed=1)
    with _fleet(broker, n=1):
        with pytest.raises(RuntimeError, match="broker campaign failed"):
            run_campaign([spec], store, broker=broker)
    assert store.meta(spec.session_id)["status"] == "failed"
    # recovery: the same store resumes cleanly once evaluation works
    monkeypatch.undo()
    res = run_session(spec, store=store)
    assert len(res.trials) == 20
    assert store.meta(spec.session_id)["status"] == "done"


def test_broker_campaign_resumes_interrupted_session(broker, tmp_path):
    """Journal replay composes with the broker driver: an interrupted
    session picked up by a broker campaign finishes bit-identical to the
    never-interrupted serial run."""
    spec = SessionSpec(problem="toy_rastrigin", tuner="genetic", budget=60,
                       seed=11)
    ref = run_session(spec)
    store = SessionStore(tmp_path / "store")
    run_session(spec, store=store, stop_after=25)          # interrupted
    assert store.meta(spec.session_id)["status"] == "interrupted"
    with _fleet(broker, n=2):
        res = run_campaign([spec], store, broker=broker)[spec.session_id]
    assert _traces_equal(ref, res)
    assert store.meta(spec.session_id)["status"] == "done"


def test_stale_jobs_from_previous_driver_are_dropped(broker, tmp_path):
    """A driver killed mid-campaign leaves jobs on the queue; a restarted
    driver must drop their late results and failures (it resubmits what
    it still needs) instead of crashing on unknown job ids."""
    # stale leftovers: one job a worker will complete under the new
    # driver, one already failed
    broker.submit({"problem": "toy_quad", "pk": {}, "archs": ["v5e"],
                   "rows": [0, 1, 2], "sessions": ["ghost"]})
    dead = broker.submit({"problem": "toy_quad", "pk": {}, "archs": ["v5e"],
                          "rows": [3], "sessions": ["ghost"]})
    for _ in range(broker.max_attempts):
        jid, _payload = broker.lease("w-old", lease_s=0.01)
        while jid != dead:              # drain until we hold the doomed one
            broker.complete(jid, "w-old", {"arch_trials": {"v5e": []}})
            jid, _payload = broker.lease("w-old", lease_s=0.01)
        time.sleep(0.03)                # let the lease expire
    broker.collect()                    # pop the stale completions only...
    broker.submit({"problem": "toy_quad", "pk": {}, "archs": ["v5e"],
                   "rows": [0, 1], "sessions": ["ghost"]})  # ...leave one

    spec = SessionSpec(problem="toy_rastrigin", tuner="random", budget=20,
                       seed=2)
    ref = run_session(spec)
    store = SessionStore(tmp_path / "store")
    with _fleet(broker, n=2):
        res = run_campaign([spec], store, broker=broker)[spec.session_id]
    assert _traces_equal(ref, res)
    assert store.meta(spec.session_id)["status"] == "done"


def test_cli_status_refuses_missing_broker_db(tmp_path, capsys):
    store = SessionStore(tmp_path / "store")
    missing = tmp_path / "nope" / "queue.db"
    rc = cli_main(["status", "--store", str(store.root),
                   "--broker", str(missing)])
    assert rc == 2
    assert "no broker db" in capsys.readouterr().err
    assert not missing.exists()         # status never conjured one


def test_broker_requires_registry_problems(broker):
    spec = SessionSpec(problem="no_such_problem", tuner="random", budget=5)
    with pytest.raises(ValueError, match="registry problems"):
        run_campaign([spec], broker=broker)


def test_v1_journal_store_is_refused_loudly(broker, tmp_path):
    """The ride-along bugfix: a store last written by an older (v1,
    config-column) orchestrator gets a clear error from the broker
    driver, not a downstream failure."""
    store = SessionStore(tmp_path / "store")
    spec = SessionSpec(problem="toy_quad", tuner="random", budget=10, seed=0)
    sid = store.create(spec)
    with open(store._journal_path(sid), "w") as f:
        f.write(json.dumps({"k": 3, "c": [3, 0, 0, 0], "o": 2.0,
                            "v": True}) + "\n")
    assert store.journal_version(sid) == 1
    with pytest.raises(RuntimeError, match="v1"):
        run_campaign([spec], store, broker=broker)
    # the same store is still fine for the in-process paths
    res = run_session(spec, store=store)
    assert len(res.trials) == 10


# --------------------------------------------------------------------- #
# campaign spec defaults + CLI plumbing (satellites)
# --------------------------------------------------------------------- #
def test_campaign_grid_applies_surrogate_bo_batch_width():
    camp = Campaign.grid(problems=["toy_quad"], tuners=["surrogate_bo"],
                         budget=10)
    assert camp.specs[0].tuner_kwargs == {"batch_width": 8}
    assert CAMPAIGN_TUNER_DEFAULTS["surrogate_bo"]["batch_width"] == 8
    # explicit settings win over the default
    camp = Campaign.grid(problems=["toy_quad"], tuners=["surrogate_bo"],
                         budget=10, tuner_kwargs={"batch_width": 2})
    assert camp.specs[0].tuner_kwargs == {"batch_width": 2}
    # other tuners are untouched
    camp = Campaign.grid(problems=["toy_quad"], tuners=["random"], budget=10)
    assert camp.specs[0].tuner_kwargs == {}


def test_parse_tuner_args():
    out = _parse_tuner_args(["batch_width=16", "moves=alias", "flag=true"],
                            {"pop_size": 4})
    assert out == {"pop_size": 4, "batch_width": 16, "moves": "alias",
                   "flag": True}
    with pytest.raises(ValueError, match="k=v"):
        _parse_tuner_args(["oops"], {})


def test_cli_campaign_tuner_arg_reaches_specs(tmp_path, capsys):
    rc = cli_main(["campaign", "--problems", "toy_quad",
                   "--tuners", "surrogate_bo", "--budget", "8",
                   "--tuner-arg", "batch_width=2",
                   "--store", str(tmp_path / "store")])
    assert rc == 0
    capsys.readouterr()
    store = SessionStore(tmp_path / "store")
    sids = store.list_sessions()
    assert len(sids) == 1
    assert store.load_spec(sids[0]).tuner_kwargs == {"batch_width": 2}


def test_cli_status_reports_lease_holder(tmp_path, capsys):
    store = SessionStore(tmp_path / "store")
    spec = SessionSpec(problem="toy_quad", tuner="random", budget=10, seed=0)
    sid = store.create(spec)
    store.update_meta(sid, status="running")
    db = str(tmp_path / "queue.db")
    broker = SQLiteBroker(db)
    jid = broker.submit({"problem": "toy_quad", "archs": ["v5e"],
                         "rows": [1, 2], "sessions": [sid]})
    assert broker.lease("host9:4242:abc123", lease_s=60.0)[0] == jid
    rc = cli_main(["status", "--store", str(store.root), "--broker", db])
    out = capsys.readouterr().out
    assert rc == 0
    assert "host9:4242:abc123" in out and "ago" in out
    # a running session with no live lease shows as queued, not silent
    broker.complete(jid, "host9:4242:abc123", {"arch_trials": {"v5e": []}})
    broker.collect()
    rc = cli_main(["status", "--store", str(store.root), "--broker", db])
    out = capsys.readouterr().out
    assert rc == 0 and "(queued)" in out


# --------------------------------------------------------------------- #
# detached worker processes (SQLite only, the multi-host claim)
# --------------------------------------------------------------------- #
def _spawn_worker(db: str, *, lease: float, max_idle: float,
                  tmp: Path, tag: str) -> subprocess.Popen:
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    log = open(tmp / f"worker-{tag}.log", "w")
    return subprocess.Popen(
        [sys.executable, "-m", "repro.orchestrator", "worker",
         "--broker", db, "--workers", "2", "--lease", str(lease),
         "--poll", "0.02", "--max-idle", str(max_idle)],
        env=env, stdout=log, stderr=log, cwd=str(tmp))


def test_detached_workers_kill_one_midcampaign_trace_identical(tmp_path):
    """The CI broker smoke scenario: a real worker process is SIGKILLed
    *while it provably holds a lease* mid-campaign; lease expiry requeues
    its jobs onto a survivor (spawned only after the kill, so the
    requeue path cannot be skipped) and the finished trace equals the
    in-process run."""
    camp = Campaign.grid(problems=["toy_rastrigin"],
                         tuners=["genetic", "random"],
                         archs=["v5e", "v4"], seeds=[0], budget=100,
                         workers=2)
    ref = {s.session_id: run_session(s) for s in camp.specs}

    db = str(tmp_path / "queue.db")
    broker = SQLiteBroker(db)
    store = SessionStore(tmp_path / "store")
    doomed = _spawn_worker(db, lease=1.5, max_idle=60, tmp=tmp_path,
                           tag="doomed")
    procs = [doomed]
    result: dict = {}

    def _drive() -> None:
        result["res"] = run_campaign(camp.specs, store, broker=broker)

    driver = threading.Thread(target=_drive, daemon=True)
    driver.start()
    try:
        # wait until the doomed worker actually holds a lease...
        watch = SQLiteBroker(db)
        deadline = time.time() + 60
        while not watch.in_flight():
            assert time.time() < deadline, "worker never leased a job"
            assert driver.is_alive(), \
                "campaign finished before any lease was observed"
            time.sleep(0.002)
        # ...then SIGKILL it mid-lease and bring up the survivor
        doomed.kill()
        assert driver.is_alive(), "kill must land mid-campaign"
        procs.append(_spawn_worker(db, lease=1.5, max_idle=60, tmp=tmp_path,
                                   tag="survivor"))
        driver.join(timeout=120)
        assert not driver.is_alive()
    finally:
        for p in procs:
            p.kill()
            p.wait(timeout=30)
    assert doomed.returncode == -signal.SIGKILL
    res = result["res"]
    for sid in ref:
        assert _traces_equal(ref[sid], res[sid]), sid
        assert store.meta(sid)["status"] == "done"


# --------------------------------------------------------------------- #
# worker metrics: the broker-backed fleet telemetry table
# --------------------------------------------------------------------- #
def test_metrics_snapshot_after_complete(broker):
    """A real BrokerWorker records its per-job counters into the broker's
    metrics table as part of serving a job — no telemetry opt-in needed —
    and the totals match the session's published trace."""
    from repro.telemetry.metrics import aggregate_samples

    spec = SessionSpec(problem="toy_quad", tuner="genetic", budget=24,
                       seed=3)
    with _fleet(broker, n=1):
        res = run_session(spec, broker=broker)
    per_worker = aggregate_samples(broker.read_metrics())
    assert len(per_worker) == 1
    (_, m), = per_worker.items()
    assert m["jobs"] >= 1
    assert m["evals"] == len(res.trials)          # every trial billed once
    assert m["eval_s"] > 0.0
    assert m.get("poison", 0.0) == 0.0
    assert m["configs_per_s"] > 0.0               # gauge, last batch


def test_metrics_aggregation_matches_per_job_ground_truth(broker):
    """Counters sum and gauges last-write-win across an explicit sequence
    of per-job recordings — the aggregation contract, backend-identical."""
    from repro.telemetry.metrics import aggregate_samples

    truth = {"w1": [(4, 0.25), (6, 0.5)], "w2": [(8, 1.0)]}
    for w, jobs in truth.items():
        for evals, secs in jobs:
            broker.record_metrics(w, [
                {"name": "jobs", "value": 1, "kind": "counter"},
                {"name": "evals", "value": evals, "kind": "counter"},
                {"name": "eval_s", "value": secs, "kind": "counter"},
                {"name": "configs_per_s", "value": evals / secs,
                 "kind": "gauge"},
            ])
    agg = aggregate_samples(broker.read_metrics())
    for w, jobs in truth.items():
        assert agg[w]["jobs"] == len(jobs)
        assert agg[w]["evals"] == sum(e for e, _ in jobs)
        assert agg[w]["eval_s"] == pytest.approx(sum(s for _, s in jobs))
        e, s = jobs[-1]                           # gauge: last write wins
        assert agg[w]["configs_per_s"] == pytest.approx(e / s)
    # filtered reads
    assert {r["worker"] for r in broker.read_metrics(worker="w1")} == {"w1"}
    assert {r["name"] for r in broker.read_metrics(name="jobs")} == {"jobs"}


def test_metrics_survive_requeue_and_collect(broker):
    """A worker that dies mid-lease (stops heartbeating, never completes)
    keeps its recorded counters: samples are append-only and exempt from
    ``collect``/``reap`` cleanup, so a post-mortem sees the dead worker's
    progress next to the survivor's."""
    from repro.telemetry.metrics import aggregate_samples

    jid = broker.submit({"problem": "toy_quad", "archs": ["v5e"],
                         "rows": [1], "sessions": []})
    assert broker.lease("w-dead", lease_s=0.05)[0] == jid
    # the doomed worker got some work done before the SIGKILL-equivalent
    broker.record_metrics("w-dead", [
        {"name": "jobs", "value": 1, "kind": "counter"},
        {"name": "evals", "value": 3, "kind": "counter"}])
    time.sleep(0.1)                               # lease expires, no reap
    assert broker.lease("w-live", lease_s=30.0)[0] == jid   # requeued
    broker.record_metrics("w-live", [
        {"name": "jobs", "value": 1, "kind": "counter"},
        {"name": "evals", "value": 3, "kind": "counter"}])
    assert broker.complete(jid, "w-live", {"arch_trials": {"v5e": []}})
    done, _ = broker.collect()                    # job rows cleaned up...
    assert list(done) == [jid]
    agg = aggregate_samples(broker.read_metrics())
    assert agg["w-dead"]["evals"] == 3            # ...metrics rows are not
    assert agg["w-live"]["evals"] == 3
    assert agg["w-dead"]["jobs"] == agg["w-live"]["jobs"] == 1


def test_in_flight_reports_stale_leases(broker):
    """``in_flight`` flags an expired lease (and its negative remaining
    time) without requeueing anything — it is a pure read for dashboards."""
    jid = broker.submit({"problem": "toy_quad", "archs": ["v5e"],
                         "rows": [1], "sessions": []})
    broker.lease("w-slow", lease_s=0.05)
    flight = broker.in_flight()
    assert len(flight) == 1 and flight[0]["stale"] is False
    assert flight[0]["lease_remaining"] > 0.0
    time.sleep(0.1)
    flight = broker.in_flight()
    assert flight[0]["stale"] is True
    assert flight[0]["lease_remaining"] < 0.0
    assert flight[0]["job"] == jid
    # still leased from the queue's point of view until someone reaps
    assert broker.counts()[LEASED] == 1


# --------------------------------------------------------------------- #
# injected clocks (staticcheck wall-clock contract)
# --------------------------------------------------------------------- #

@pytest.fixture(params=["memory", "sqlite"])
def clocked_broker(request, tmp_path):
    """Both backends on a settable fake clock — lease arithmetic becomes
    a pure function of the injected time, no sleeps."""
    t = [1000.0]
    clock = lambda: t[0]
    b = (MemoryBroker(clock=clock) if request.param == "memory"
         else SQLiteBroker(tmp_path / "queue.db", clock=clock))
    yield b, t
    b.close()


def test_lease_expiry_follows_injected_clock(clocked_broker):
    """Advancing the fake clock past the lease expires it — no real time
    passes, proving every lease timestamp comes from the injected clock
    (the regression the staticcheck wall-clock rule guards)."""
    broker, t = clocked_broker
    jid = broker.submit({"problem": "toy_quad", "archs": ["v5e"],
                         "rows": [1], "sessions": []})
    assert broker.lease("w-a", lease_s=5.0)[0] == jid
    assert broker.reap() == 0                     # lease still live
    assert broker.lease("w-b", lease_s=5.0) is None
    t[0] += 5.1                                   # fake time passes
    assert broker.lease("w-b", lease_s=5.0)[0] == jid   # auto-reap + release
    t[0] += 0.1
    flight = broker.in_flight()
    assert flight[0]["stale"] is False
    assert flight[0]["lease_remaining"] == pytest.approx(4.9, abs=1e-6)


def test_heartbeat_extends_injected_clock_lease(clocked_broker):
    broker, t = clocked_broker
    jid = broker.submit({"problem": "toy_quad", "archs": ["v5e"],
                         "rows": [1], "sessions": []})
    broker.lease("w-a", lease_s=5.0)
    t[0] += 4.0
    assert broker.heartbeat(jid, "w-a", lease_s=5.0)
    t[0] += 4.0                                   # 8s total < 4s + renewed 5s
    assert broker.reap() == 0
    t[0] += 1.1
    assert broker.reap() == 1                     # renewed lease now expired


def test_store_metadata_stamps_from_injected_clock(tmp_path):
    t = [42.0]
    store = SessionStore(tmp_path / "sessions", clock=lambda: t[0])
    prob = registry.make_problem("toy_quad")
    spec = SessionSpec(problem="toy_quad", tuner="random_search",
                       arch="v5e", budget=4, seed=0)
    sid = store.create(spec)
    meta = store.meta(sid)
    assert meta["created_at"] == 42.0 and meta["updated_at"] == 42.0
    t[0] = 99.0
    meta = store.update_meta(sid, evaluated=1)
    assert meta["created_at"] == 42.0 and meta["updated_at"] == 99.0


def test_worker_max_idle_follows_injected_clock():
    """A BrokerWorker on a monotonic fake clock exits its run loop when
    the injected idle age crosses max_idle_s — without waiting real
    seconds for it."""
    broker = MemoryBroker()
    t = [0.0]

    class Tick:
        def __call__(self):
            t[0] += 2.0        # every poll advances fake time 2s
            return t[0]

    w = BrokerWorker(broker, workers=1, poll_s=0.0, clock=Tick())
    start = time.monotonic()
    w.run(max_idle_s=10.0)     # empty queue: exits after ~5 fake polls
    assert time.monotonic() - start < 5.0
    assert t[0] > 10.0
