"""Output-exactness of the beyond-paper SPMD optimizations (§Perf):
kv-head replication, scatter cache updates, q-chunked softmax — all must
be bitwise-tolerant no-ops mathematically."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A

TOL = 1e-5


@pytest.fixture(scope="module")
def gqa():
    p, _ = A.make_gqa(jax.random.key(0), 64, 8, 2, 8)
    x = jax.random.normal(jax.random.key(1), (2, 64, 64), jnp.float32)
    return p, x, jnp.arange(64)[None]


def test_kv_repeat_is_exact(gqa):
    p, x, pos = gqa
    o1, c1 = A.gqa_forward(p, x, positions=pos, kv_repeat=1)
    for r in (2, 4):
        o2, c2 = A.gqa_forward(p, x, positions=pos, kv_repeat=r)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=TOL)
        assert c2["k"].shape[2] == 2 * r


def test_scatter_equals_blend(gqa):
    p, x, pos = gqa
    cache = {"k": jnp.zeros((2, 16, 2, 8)), "v": jnp.zeros((2, 16, 2, 8))}
    tok = x[:, :1]
    ob, cb = A.gqa_decode(p, tok, cache, position=3, scatter=False)
    os_, cs = A.gqa_decode(p, tok, cache, position=3, scatter=True)
    np.testing.assert_allclose(np.asarray(ob), np.asarray(os_), atol=TOL)
    np.testing.assert_array_equal(np.asarray(cb["k"]), np.asarray(cs["k"]))


def test_chunked_softmax_is_exact():
    p, _ = A.make_gqa(jax.random.key(0), 64, 8, 2, 8)
    x = jax.random.normal(jax.random.key(1), (1, 4096, 64), jnp.float32)
    pos = jnp.arange(4096)[None]
    for window in (None, 128):
        o1, _ = A.gqa_forward(p, x, positions=pos, window=window, opt=False)
        o2, _ = A.gqa_forward(p, x, positions=pos, window=window, opt=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=TOL)


def test_decode_matches_forward_with_all_opts():
    """prefill (kv_repeat) -> scatter decode == plain full forward."""
    p, _ = A.make_gqa(jax.random.key(0), 64, 8, 2, 8)
    x = jax.random.normal(jax.random.key(1), (2, 9, 64), jnp.float32)
    o_ref, _ = A.gqa_forward(p, x, positions=jnp.arange(9)[None])
    _, cache = A.gqa_forward(p, x[:, :8], positions=jnp.arange(8)[None],
                             kv_repeat=4, make_cache=True)
    cache = {k: jnp.pad(v, ((0, 0), (0, 2), (0, 0), (0, 0)))
             for k, v in cache.items()}
    od, _ = A.gqa_decode(p, x[:, 8:9], cache, position=8, kv_repeat=4,
                         scatter=True)
    np.testing.assert_allclose(np.asarray(od[:, 0]), np.asarray(o_ref[:, 8]),
                               atol=1e-4)


def test_mla_scatter_equals_blend():
    p, _ = A.make_mla(jax.random.key(0), 64, 4, kv_lora=16, q_lora=32,
                      nope_dim=8, rope_dim=4)
    x = jax.random.normal(jax.random.key(1), (2, 1, 64), jnp.float32)
    cache = {"ckv": jnp.zeros((2, 8, 16)), "k_pe": jnp.zeros((2, 8, 4))}
    ob, cb = A.mla_decode(p, x, cache, position=2, scatter=False)
    os_, cs = A.mla_decode(p, x, cache, position=2, scatter=True)
    np.testing.assert_allclose(np.asarray(ob), np.asarray(os_), atol=TOL)
    np.testing.assert_array_equal(np.asarray(cb["ckv"]), np.asarray(cs["ckv"]))


def test_optimized_model_smoke():
    """Full model with every opt flag on (CPU, no mesh): forward/decode
    still correct vs the baseline flags."""
    import dataclasses
    from repro.configs import ARCHS, reduce_config
    from repro.models import build_model
    cfg0 = reduce_config(ARCHS["qwen3-8b"])
    cfg1 = dataclasses.replace(cfg0, opt_attn=True, opt_moe=True,
                               opt_scatter_cache=True, kv_repeat=2)
    m0, m1 = build_model(cfg0), build_model(cfg1)
    params = m0.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg0.vocab)
    l0, _, _ = m0.forward(params, {"tokens": toks})
    l1, _, _ = m1.forward(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=2e-2, atol=2e-2)
    cache = m1.init_cache(2, max_len=16)
    lg, cache = m1.decode_step(params, cache, toks[:, :1], 0)
    assert bool(jnp.all(jnp.isfinite(lg)))
