"""Serving engine behaviour: continuous batching, slot lifecycle, prefill
-> decode consistency, ring-buffer splicing."""

import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, reduce_config
from repro.serve.decode import Request, ServeConfig, ServingEngine


def _engine(arch="qwen3-8b", **kw):
    cfg = reduce_config(ARCHS[arch])
    sc = ServeConfig(**{**dict(n_slots=2, max_len=64, max_new_tokens=8,
                               temperature=0.0, seed=0), **kw})
    return ServingEngine(cfg, sc), cfg


def _reqs(cfg, n, lens, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=lens[i % len(lens)])
                    .astype(np.int32))
            for i in range(n)]


def test_all_requests_complete_despite_oversubscription():
    engine, cfg = _engine()
    for r in _reqs(cfg, 5, lens=(3, 7, 11)):
        engine.submit(r)
    completions = engine.run()
    assert len(completions) == 5
    assert sorted(c.uid for c in completions) == list(range(5))
    for c in completions:
        assert 1 <= len(c.tokens) <= 8
        assert c.finished_reason in ("eos", "length")


def test_continuous_batching_mixes_sequence_lengths():
    """Slots admitted at different times decode in the same lockstep batch —
    per-slot positions must diverge."""
    engine, cfg = _engine(n_slots=2, max_new_tokens=6)
    reqs = _reqs(cfg, 3, lens=(4, 9))
    engine.submit(reqs[0])
    engine.step()                    # admit r0 alone
    engine.submit(reqs[1])
    engine.submit(reqs[2])
    engine.step()                    # r1 joins mid-flight
    if engine.active.all():
        assert engine.positions[0] != engine.positions[1]
    engine.run()
    assert len(engine.completions) == 3


def test_greedy_decode_matches_full_forward():
    """Engine output (prefill + spliced cache + decode steps) must equal
    greedy decoding with full-sequence forwards (the no-cache oracle)."""
    engine, cfg = _engine(n_slots=1, max_new_tokens=4, max_len=32)
    model = engine.model
    params = engine.params
    prompt = np.asarray([5, 9, 2], np.int32)
    engine.submit(Request(uid=0, prompt=prompt))
    (completion,) = engine.run()

    toks = list(prompt)
    want = []
    for _ in range(4):
        logits, _, _ = model.forward(
            params, {"tokens": jnp.asarray(toks, jnp.int32)[None]})
        nxt = int(jnp.argmax(logits[0, -1]))
        want.append(nxt)
        toks.append(nxt)
    assert completion.tokens == want, (completion.tokens, want)


def test_eos_frees_slot_early():
    engine, cfg = _engine(n_slots=1, max_new_tokens=50, max_len=64)
    # probe which token the model emits first, then use it as the EOS id
    probe = _reqs(cfg, 1, lens=(5,))[0]
    engine.submit(probe)
    first = engine.run()[0].tokens[0]
    engine2, _ = _engine(n_slots=1, max_new_tokens=50, max_len=64)
    engine2.cfg.eos_token = first
    engine2.submit(_reqs(cfg, 1, lens=(5,))[0])
    (c,) = engine2.run()
    assert c.finished_reason == "eos"
    assert len(c.tokens) == 1


def test_windowed_arch_ring_buffer_serving():
    """gemma3-style local-attention layers use ring-buffer caches shorter
    than max_len; prompts longer than the window must still serve."""
    engine, cfg = _engine(arch="gemma3-27b", n_slots=1, max_len=64,
                          max_new_tokens=4)
    win = min(s.window for s in cfg.pattern if s.window)
    prompt = np.arange(win + 9, dtype=np.int32) % cfg.vocab
    engine.submit(Request(uid=0, prompt=prompt))
    (c,) = engine.run()
    assert len(c.tokens) == 4
    assert all(0 <= t < cfg.vocab for t in c.tokens)


def test_recurrent_arch_serving():
    """RWKV6: O(1) state instead of KV rows — same engine code path."""
    engine, cfg = _engine(arch="rwkv6-1.6b", n_slots=2, max_len=48,
                          max_new_tokens=4)
    for r in _reqs(cfg, 3, lens=(3, 12)):
        engine.submit(r)
    assert len(engine.run()) == 3
