"""Analysis-layer tests: the five paper characteristics on landscapes with
known ground truth."""

import math

import numpy as np
import pytest

from repro.core.analysis.centrality import (build_ffg, pagerank,
                                            proportion_of_centrality)
from repro.core.analysis.convergence import (evals_to_reach, median_curve,
                                             random_search_curves)
from repro.core.analysis.distribution import (distribution_profile,
                                              relative_performance,
                                              speedup_over_median,
                                              top_cluster_fraction)
from repro.core.analysis.importance import (feature_importance,
                                            important_params, reduced_space)
from repro.core.analysis.portability import portability_matrix
from repro.core.analysis.spacestats import space_stats
from repro.core.mlmodel import (GradientBoostedTrees, permutation_importance,
                                r2_score)
from repro.core.problem import FunctionProblem
from repro.core.results import ResultTable
from repro.core.space import Param, SearchSpace


def _table(space, fn, arch="v5e", protocol="exhaustive"):
    prob = FunctionProblem(space, fn)
    trials = prob.exhaustive(arch)
    return ResultTable.from_trials(prob, arch, trials, protocol)


def _grid_space(n=2, k=8):
    return SearchSpace([Param(f"p{i}", tuple(range(k))) for i in range(n)])


# ------------------------------------------------------------------ #
# distribution / speedup (Fig 1, Fig 4)
# ------------------------------------------------------------------ #
def test_relative_performance_and_speedup():
    space = _grid_space(1, 10)
    table = _table(space, lambda c, a: float(c["p0"] + 1))   # 1..10 seconds
    rel = relative_performance(table)
    assert rel.max() == pytest.approx(1.0)                   # best == 1
    assert rel.min() == pytest.approx(0.1)
    # median runtime 5.5s, best 1s -> 5.5x speedup over median
    assert speedup_over_median(table) == pytest.approx(5.5)


def test_distribution_profile_monotone():
    space = _grid_space(2, 12)
    table = _table(space, lambda c, a: 1.0 + c["p0"] * 0.3 + c["p1"] ** 1.7)
    prof = distribution_profile(table, quantiles=np.linspace(0, 1, 21))
    assert len(prof["quantiles"]) == 21 and prof["n"] == 144
    perf = np.array(prof["rel_perf"])
    assert np.all(np.diff(perf) >= -1e-12)                   # quantile curve
    # median-normalized curve crosses 1.0 at the median quantile
    mid = np.array(prof["rel_to_median"])[10]
    assert mid == pytest.approx(1.0, rel=0.05)


def test_top_cluster_fraction_detects_hotspot_shape():
    """A landscape with a big near-optimal cluster (Hotspot's signature) has
    a much larger top-cluster fraction than a needle-in-haystack one."""
    space = _grid_space(2, 16)                               # 256 configs

    def clustered(c, a):          # ~25% of configs are within 10% of best
        return 1.0 if (c["p0"] < 8 and c["p1"] < 8) else 12.0

    def needle(c, a):
        return 1.0 if (c["p0"] == 3 and c["p1"] == 7) else 12.0

    f_clu = top_cluster_fraction(_table(space, clustered), within=0.10)
    f_ndl = top_cluster_fraction(_table(space, needle), within=0.10)
    assert f_clu > 0.2 and f_ndl < 0.01


# ------------------------------------------------------------------ #
# convergence (Fig 2)
# ------------------------------------------------------------------ #
def test_random_search_convergence_properties():
    space = _grid_space(2, 16)
    table = _table(space, lambda c, a: 1.0 + abs(c["p0"] - 7) + abs(c["p1"] - 3))
    curves = random_search_curves(table, budget=100, repeats=30, seed=1)
    assert curves.shape == (30, 100)
    med = median_curve(table, budget=256, repeats=30, seed=1)
    assert np.all(np.diff(med) >= -1e-12)                    # monotone up
    assert med[-1] == pytest.approx(1.0)    # exhausted w/o replacement
    # clustered landscapes converge faster than needles (paper C2)
    t_clu = _table(space, lambda c, a: 1.0 if c["p0"] < 8 else 10.0)
    t_ndl = _table(space, lambda c, a: 1.0 if (c["p0"], c["p1"]) == (3, 7)
                   else 10.0)
    m_clu = median_curve(t_clu, budget=60, repeats=30, seed=2)
    m_ndl = median_curve(t_ndl, budget=60, repeats=30, seed=2)
    e_clu, e_ndl = evals_to_reach(m_clu, 0.9), evals_to_reach(m_ndl, 0.9)
    assert e_clu != -1 and (e_ndl == -1 or e_clu < e_ndl)


# ------------------------------------------------------------------ #
# centrality (Fig 3)
# ------------------------------------------------------------------ #
def test_ffg_structure_on_known_landscape():
    """1-D monotone landscape: every node flows toward the single minimum;
    the minimum holds all the 'good minima' mass -> proportion == 1."""
    space = _grid_space(1, 10)
    table = _table(space, lambda c, a: float(c["p0"] + 1))
    ffg = build_ffg(space, table)
    assert ffg.n == 10
    assert ffg.minima.sum() == 1                            # unique minimum
    pr = pagerank(ffg)
    assert pr.sum() == pytest.approx(1.0, abs=1e-6)
    poc = proportion_of_centrality(space, table, p=0.05)
    assert poc == pytest.approx(1.0)


def test_centrality_separates_easy_from_deceptive():
    """A global optimum hidden behind a fitness wall gets little random-walk
    mass (hard for local search); a smooth unimodal landscape scores 1."""
    space = _grid_space(2, 11)

    def easy(c, a):
        return 1.0 + 0.1 * (abs(c["p0"] - 5) + abs(c["p1"] - 5))

    def deceptive(c, a):
        x, y = c["p0"], c["p1"]
        if (x, y) == (5, 5):
            return 0.5                          # global min, walled off
        if x == 5 or y == 5:
            return 3.0                          # the wall
        return 1.0 + 0.01 * (x + y)             # wide basin -> (0,0) @ 1.0

    poc_easy = proportion_of_centrality(space, _table(space, easy), p=0.05)
    poc_dec = proportion_of_centrality(space, _table(space, deceptive), p=0.05)
    assert poc_easy == pytest.approx(1.0)
    assert poc_dec < 0.5 * poc_easy


# ------------------------------------------------------------------ #
# PFI / surrogate (Fig 6, Table VIII reduction)
# ------------------------------------------------------------------ #
def test_gbdt_fits_and_pfi_finds_important_feature():
    rng = np.random.default_rng(0)
    X = rng.integers(0, 8, size=(600, 4))
    y = 3.0 * X[:, 1] + 0.3 * X[:, 3] + rng.normal(0, 0.05, 600)
    model = GradientBoostedTrees(n_trees=80, max_depth=4, seed=0).fit(X, y)
    assert r2_score(y, model.predict(X)) > 0.97
    pfi = permutation_importance(model, X, y, n_repeats=3, seed=0)
    assert pfi[1] == max(pfi)
    assert pfi[1] > 5 * max(pfi[0], pfi[2])


def test_feature_importance_pipeline_and_reduction():
    space = SearchSpace([Param("big", tuple(range(8))),
                         Param("tiny", tuple(range(8))),
                         Param("dead", tuple(range(4)))])

    def fn(c, a):
        # 'big' dominates; 'big'×'tiny' interaction; 'dead' is irrelevant
        return math.exp(0.5 * c["big"] + 0.08 * c["big"] * (c["tiny"] > 4))

    table = _table(space, fn)
    imp = feature_importance(table, seed=0)
    by_name = dict(zip(imp["params"], imp["pfi"]))
    assert imp["r2"] > 0.95
    assert by_name["big"] > 10 * max(by_name["dead"], 1e-9)
    keep = important_params({"v5e": imp}, threshold=0.05)
    assert "big" in keep and "dead" not in keep
    best_cfg = space.decode(table.best()[0])
    red = reduced_space(space, {"v5e": imp}, best_cfg, threshold=0.05)
    assert red.cardinality < space.cardinality


# ------------------------------------------------------------------ #
# portability (Fig 5)
# ------------------------------------------------------------------ #
def test_portability_matrix_properties():
    space = _grid_space(2, 8)

    def make(shift):
        return _table(space, lambda c, a: 1.0 + (c["p0"] - shift) ** 2
                      + 0.5 * (c["p1"] - shift) ** 2)

    tables = {"v5e": make(2), "v5p": make(2), "v4": make(6)}
    m = portability_matrix(tables)
    mat = np.array(m["matrix"])
    names = m["archs"]
    # diagonal is exactly 1 (own optimum), all entries in (0, 1]
    assert np.allclose(np.diag(mat), 1.0)
    assert (mat > 0).all() and (mat <= 1.0 + 1e-9).all()
    # same-optimum archs transfer perfectly; shifted arch does not
    i5e, i5p, i4 = (names.index(a) for a in ("v5e", "v5p", "v4"))
    assert mat[i5e][i5p] == pytest.approx(1.0)
    assert mat[i5e][i4] < 0.9


# ------------------------------------------------------------------ #
# Table VIII accounting
# ------------------------------------------------------------------ #
def test_space_stats_counts():
    space = SearchSpace(
        [Param("a", (1, 2, 3, 4)), Param("b", (1, 2))],
        [__import__("repro.core.space", fromlist=["Constraint"]).Constraint(
            "even", lambda c: (c["a"] + c["b"]) % 2 == 0)])

    prob = FunctionProblem(space, lambda c, a: float(c["a"]))
    st = space_stats(prob, archs=("v5e",))
    assert st["cardinality"] == 8
    assert st["constrained"] == 4
    assert st["valid"]["v5e"] == 4
