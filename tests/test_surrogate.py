"""Surrogate warm-start suite: harvest/leakage guards, model round-trips,
the checksummed model store (quarantine-on-corrupt), the two tuner seams
(warm start + screening), spec identity, and the CLI.

The transfer contract under test: a model trained on *other*
architectures' journaled history must (a) beat a shuffled-label baseline
on a held-out architecture, (b) never train on its own screened
estimates, and (c) leave unwarmed runs bit-identical to a world where
the model store does not exist.
"""

from __future__ import annotations

import json
import math
import subprocess
import sys

import numpy as np
import pytest

from repro.core.mlmodel import GradientBoostedTrees, RegressionTree
from repro.core.results import ResultTable
from repro.core.surrogate import (ESTIMATED_INFO, Harvest, KernelSurrogate,
                                  ModelStore, SurrogateScreen)
from repro.core.surrogate.store import (HEADER_FIELDS, MAGIC, ModelStoreError,
                                        parse_model, section_checksum)
from repro.core.tuners import TUNERS
from repro.core.tuners.base import run_tuner
from repro.orchestrator.cli import main as cli_main
from repro.orchestrator.registry import make_problem
from repro.orchestrator.runner import resume_session, run_session
from repro.orchestrator.session import SessionSpec
from repro.orchestrator.store import SessionStore

SMALL = {"n_trees": 24, "max_depth": 4, "min_samples_leaf": 2, "seed": 0}


def _problem():
    return make_problem("toy_quad")


def _objectives(prob, rows, arch):
    sp = prob.space
    return [prob.evaluate(sp.from_flat_index(int(r)), arch).objective
            for r in rows]


def _training_set(archs=("v4", "v5e", "v5p"), n=240, seed=0):
    prob = _problem()
    h = Harvest("toy_quad", prob.space)
    rng = np.random.default_rng(seed)
    rows = rng.choice(prob.space.cardinality, size=n, replace=False)
    for arch in archs:
        h.add_rows(rows.tolist(), arch, _objectives(prob, rows, arch))
    return prob, h.build()


def _model(archs=("v4", "v5e", "v5p"), n=240, seed=0, params=SMALL):
    prob, ts = _training_set(archs, n, seed)
    return prob, KernelSurrogate.fit(ts, params=params)


# --------------------------------------------------------------------- #
# mlmodel degenerate inputs (the fit() hardening)
# --------------------------------------------------------------------- #
def test_tree_fit_empty():
    t = RegressionTree().fit(np.empty((0, 3)), np.empty(0))
    assert t.predict(np.array([[1.0, 2.0, 3.0]])).shape == (1,)


def test_tree_fit_single_row():
    t = RegressionTree().fit(np.array([[1.0, 2.0]]), np.array([5.0]))
    assert t.predict(np.array([[9.0, 9.0]]))[0] == pytest.approx(5.0)


def test_tree_fit_constant_labels():
    X = np.arange(20, dtype=float).reshape(10, 2)
    t = RegressionTree().fit(X, np.full(10, 3.25))
    assert np.allclose(t.predict(X), 3.25)


def test_gbdt_fit_empty():
    m = GradientBoostedTrees(n_trees=3).fit(np.empty((0, 2)), np.empty(0))
    assert m.predict(np.array([[1.0, 1.0]])).shape == (1,)


def test_gbdt_fit_flat_input_reshaped():
    # 1-D X must not crash: reshaped to a column
    m = GradientBoostedTrees(n_trees=3).fit(
        np.arange(8, dtype=float), np.arange(8, dtype=float))
    assert m.predict(np.array([[3.0]])).shape == (1,)


# --------------------------------------------------------------------- #
# harvest
# --------------------------------------------------------------------- #
def test_harvest_basic_schema():
    prob, ts = _training_set(archs=("v4", "v5e"), n=50)
    assert ts.X.shape == (100, len(prob.space.params) + 1)
    assert ts.param_names == prob.space.param_names
    # trailing column is the arch ordinal in vocabulary order
    assert set(ts.X[:, -1].tolist()) == {ts.archs.index("v4"),
                                         ts.archs.index("v5e")}
    # target is log seconds
    assert np.all(np.isfinite(ts.y))


def test_harvest_skips_nonfinite_and_nonpositive():
    prob = _problem()
    h = Harvest("toy_quad", prob.space)
    added = h.add_rows([1, 2, 3, 4], "v5e",
                       [1.0, math.inf, math.nan, -2.0])
    assert added == 1
    assert len(h.build()) == 1


def test_harvest_dedups_row_arch_pairs():
    prob = _problem()
    h = Harvest("toy_quad", prob.space)
    assert h.add_rows([7, 7], "v5e", [1.0, 2.0]) == 1
    assert h.add_rows([7], "v5e", [3.0]) == 0
    assert h.add_rows([7], "v4", [3.0]) == 1     # same row, new arch
    ts = h.build()
    assert len(ts) == 2
    # keep-first: the v5e objective is the original 1.0
    i = int(np.argmax(ts.X[:, -1] == ts.archs.index("v5e")))
    assert ts.y[i] == pytest.approx(math.log(1.0))


def test_harvest_exclude_and_unknown_archs():
    prob = _problem()
    h = Harvest("toy_quad", prob.space, exclude_archs=("v4",))
    assert h.add_rows([1], "v4", [1.0]) == 0
    assert h.add_rows([1], "gpu-z9", [1.0]) == 0   # not in vocabulary
    assert h.add_rows([1], "v5e", [1.0]) == 1


def test_harvest_add_table():
    prob = _problem()
    trials = prob.exhaustive(arch="v5e", limit=32)
    table = ResultTable.from_trials(prob, "v5e", trials, "exhaustive")
    h = Harvest("toy_quad", prob.space)
    assert h.add_table(table) == 32
    assert h.n_sources == 1
    # wrong problem: ignored
    table2 = ResultTable.from_trials(prob, "v5e", trials, "exhaustive")
    table2.problem = "other"
    assert h.add_table(table2) == 0


def test_harvest_split_arch():
    _, ts = _training_set(archs=("v4", "v5e"), n=40)
    rest, held = ts.split_arch("v5e")
    assert len(rest) == 40 and len(held) == 40
    assert np.all(held.X[:, -1] == ts.archs.index("v5e"))
    assert not np.any(rest.X[:, -1] == ts.archs.index("v5e"))


def test_harvest_add_store_skips_estimated(tmp_path):
    """The leakage guard: screened (model-estimated) journal records are
    never harvested as training rows."""
    prob, model = _model(n=120)
    store = SessionStore(tmp_path / "s", clock=lambda: 0.0)
    screen = SurrogateScreen(model, prob.space, "v5e", measure_frac=0.5)
    spec = SessionSpec(problem="toy_quad", tuner="random", arch="v5e",
                       budget=24, seed=9, workers=2)
    store.create(spec)
    res = run_session(spec, store=store, screen=screen)
    n_est = sum(1 for t in res.trials if t.info.get("estimated"))
    assert n_est > 0
    h = Harvest("toy_quad", prob.space)
    added = h.add_store(store)
    assert h.n_skipped_estimated == n_est
    # journal estimates skipped AND the published table excludes them
    # (publish_trace drops estimated trials), so nothing leaks via add_db
    assert added == len(res.trials) - n_est


# --------------------------------------------------------------------- #
# model: fit, predict, transfer, serialization
# --------------------------------------------------------------------- #
def test_model_recovers_ranking():
    # full-strength fit: ranking needs the default tree count, not the
    # suite's fast SMALL params
    prob, model = _model(n=400, params=None)
    # the warm-queue contract: predicted-top rows on an arch the model
    # never saw are near-optimal (true optimum objective is 1.0 at an
    # arbitrary point in a space whose median objective is ~38)
    top = model.top_rows(prob.space, "v6e", k=8)
    best_true = min(prob.evaluate(prob.space.from_flat_index(r),
                                  "v6e").objective for r in top)
    assert best_true <= 3.0
    preds = model.predict_rows(prob.space, top, "v6e")
    assert list(preds) == sorted(preds)
    # and gross ranking is right: optimum predicted faster than the worst
    opt = prob.space.flat_index({f"p{i}": 2 for i in range(4)})
    worst = prob.space.flat_index({f"p{i}": 7 for i in range(4)})
    p = model.predict_rows(prob.space, [opt, worst], "v6e")
    assert p[0] < p[1]


def test_model_unknown_arch_raises():
    prob, model = _model(archs=("v4", "v5e"))
    with pytest.raises(ValueError, match="not in model vocabulary"):
        model.predict_rows(prob.space, [0], "hal9000")


def test_model_heldout_beats_shuffled_baseline():
    """The transfer/leakage guard: held-out-arch R² must beat a model
    trained on the same rows with permuted labels."""
    prob, ts = _training_set(archs=("v4", "v5e", "v5p"), n=200)
    rest, held = ts.split_arch("v5p")
    model = KernelSurrogate.fit(rest, params=SMALL)
    r2 = model.r2(held)
    from dataclasses import replace
    perm = np.random.default_rng(1).permutation(len(rest))
    shuffled = KernelSurrogate.fit(replace(rest, y=rest.y[perm]),
                                   params=SMALL)
    assert r2 > 0.5
    assert r2 > shuffled.r2(held) + 0.3


def test_model_top_params_exclude_arch():
    prob, ts = _training_set(n=150)
    model = KernelSurrogate.fit(ts, params=SMALL)
    top = model.top_params(ts, k=3)
    assert len(top) == 3 and "arch" not in top
    assert set(top) <= set(prob.space.param_names)


def test_model_serialization_bit_identical(tmp_path):
    prob, model = _model(n=150)
    store = ModelStore(tmp_path, clock=lambda: 42.0)
    store.save(model)
    loaded, problems = store.load("toy_quad")
    assert problems == [] and loaded is not None
    rows = np.arange(64)
    np.testing.assert_array_equal(
        model.predict_rows(prob.space, rows, "v5e"),
        loaded.predict_rows(prob.space, rows, "v5e"))
    assert loaded.archs == model.archs
    assert loaded.param_names == model.param_names
    assert loaded.n_rows == model.n_rows


def test_model_payload_requires_fit():
    with pytest.raises(ValueError, match="not fitted"):
        KernelSurrogate("k", ("a",), ("v5e",)).payload()


# --------------------------------------------------------------------- #
# model store: header grammar, checksums, quarantine
# --------------------------------------------------------------------- #
def _saved(tmp_path, **kw):
    _, model = _model(n=100, **kw)
    store = ModelStore(tmp_path, clock=lambda: 0.0)
    return store, store.save(model)


def test_store_header_grammar(tmp_path):
    store, path = _saved(tmp_path)
    doc = json.loads(path.read_text())
    assert set(doc["header"]) == set(HEADER_FIELDS)
    assert doc["header"]["magic"] == MAGIC
    assert doc["header"]["sections"]["model"] == \
        section_checksum(doc["model"])
    assert store.list_models() == ["toy_quad"]


def test_store_load_missing(tmp_path):
    store = ModelStore(tmp_path)
    model, problems = store.load("nope")
    assert model is None
    assert problems and "no model" in problems[0]


@pytest.mark.parametrize("mutate,expect", [
    (lambda d: d.update(header={**d["header"], "magic": "evil"}),
     "bad magic"),
    (lambda d: d.update(header={**d["header"], "version": 99}),
     "unsupported version"),
    (lambda d: d.update(header={**d["header"], "surprise": 1}),
     "undocumented header field"),
    (lambda d: d["model"]["trees"].pop(),
     "checksum mismatch"),
    (lambda d: d.pop("model"),
     "missing model section"),
])
def test_store_corrupt_variants_quarantined(tmp_path, mutate, expect):
    store, path = _saved(tmp_path)
    doc = json.loads(path.read_text())
    mutate(doc)
    path.write_text(json.dumps(doc))
    model, problems = store.load("toy_quad")
    assert model is None
    assert any(expect in p for p in problems)
    # original moved aside with a reason note, never reparsed
    assert not path.exists()
    qdir = tmp_path / "quarantine"
    bads = list(qdir.glob("*.bad"))
    assert len(bads) == 1
    reason = bads[0].with_suffix(bads[0].suffix + ".reason").read_text()
    assert expect in reason


def test_store_garbage_bytes_quarantined(tmp_path):
    store, path = _saved(tmp_path)
    path.write_bytes(b"\x00\xffnot json")
    model, problems = store.load("toy_quad")
    assert model is None and "quarantined" in problems[0]


def test_store_quarantine_numbering(tmp_path):
    store, path = _saved(tmp_path)
    path.write_text("junk")
    store.load("toy_quad")
    # second corrupt file with the same name gets the next number
    path.write_text("junk again")
    store.load("toy_quad")
    names = sorted(p.name for p in (tmp_path / "quarantine").glob("*.bad"))
    assert names == ["toy_quad.model.json.0.bad", "toy_quad.model.json.1.bad"]


def test_store_verify_dir_readonly(tmp_path):
    store, path = _saved(tmp_path)
    report = store.verify_dir()
    assert report == {"ok": ["toy_quad"], "problems": {}}
    path.write_text("junk")
    report = store.verify_dir()
    assert "toy_quad.model.json" in report["problems"]
    assert path.exists()               # verify never quarantines


def test_parse_model_strict_raises(tmp_path):
    with pytest.raises(ModelStoreError, match="not JSON"):
        parse_model(b"nope")
    with pytest.raises(ModelStoreError, match="missing header"):
        parse_model(b"{}")


# --------------------------------------------------------------------- #
# warm-start seam
# --------------------------------------------------------------------- #
def _warm_rows(prob):
    opt = prob.space.flat_index({f"p{i}": 2 for i in range(4)})
    return [opt, opt + 1, opt + 8]


@pytest.mark.parametrize("tuner_name", sorted(TUNERS))
def test_warm_rows_proposed_first(tuner_name):
    prob = _problem()
    warm = _warm_rows(prob)
    t = TUNERS[tuner_name](prob.space, seed=1)
    res = run_tuner(t, prob, budget=20, warm_start=warm)
    got = [prob.space.flat_index(x.config) for x in res.trials[:3]]
    assert got == warm
    assert t.warm_started and t._warm_adopted
    # the warm queue contains the optimum, so best is found immediately
    assert res.best.objective == 1.0


@pytest.mark.parametrize("tuner_name", sorted(TUNERS))
def test_warm_disabled_is_bit_identical(tuner_name):
    """The rng-stream contract: constructing the seam but never arming it
    must not change a single proposal."""
    prob = _problem()
    cold = run_tuner(TUNERS[tuner_name](prob.space, seed=5), prob, budget=24)
    t = TUNERS[tuner_name](prob.space, seed=5)
    t.set_warm_start([])               # empty queue == disabled
    warm = run_tuner(t, prob, budget=24)
    assert [x.config for x in cold.trials] == [x.config for x in warm.trials]


def test_set_warm_start_filters_invalid_rows():
    prob = _problem()
    t = TUNERS["random"](prob.space, seed=0)
    card = prob.space.cardinality
    t.set_warm_start([5, -1, card + 7, 5, 9])   # dupes + out of range
    assert t._warm_queue == [5, 9]


def test_warm_adoption_walker_continues_from_best():
    """Annealing must adopt the *measured-best* warm row as its current
    state, not the last-told one."""
    prob = _problem()
    opt = prob.space.flat_index({f"p{i}": 2 for i in range(4)})
    worst = prob.space.flat_index({f"p{i}": 7 for i in range(4)})
    t = TUNERS["annealing"](prob.space, seed=2)
    run_tuner(t, prob, budget=12, warm_start=[opt, worst])
    assert t._warm_best_row == opt


def test_warm_scalar_path():
    prob = _problem()
    warm = _warm_rows(prob)
    t = TUNERS["genetic"](prob.space, seed=1)
    t._comp = None                     # force the scalar oracle path
    res = run_tuner(t, prob, budget=20, warm_start=warm)
    got = [prob.space.flat_index(x.config) for x in res.trials[:3]]
    assert got == warm and t._warm_adopted


def test_warm_spec_identity():
    base = SessionSpec(problem="toy_quad", tuner="genetic", budget=10)
    warm = SessionSpec(problem="toy_quad", tuner="genetic", budget=10,
                       warm_start=[3, 1])
    # cold spec: no key in the canonical form => pre-PR ids unchanged
    assert "warm_start" not in base.canonical()
    assert warm.canonical()["warm_start"] == [3, 1]
    assert base.session_id != warm.session_id
    rt = SessionSpec.from_json(warm.to_json())
    assert rt.warm_start == [3, 1] and rt.session_id == warm.session_id
    rt0 = SessionSpec.from_json(base.to_json())
    assert rt0.warm_start is None and rt0.session_id == base.session_id


def test_warm_session_resumes_identically(tmp_path):
    """A warm-started session interrupted mid-run and resumed equals the
    uninterrupted warm run (the spec carries the warm queue)."""
    prob = _problem()
    warm = _warm_rows(prob)
    spec = SessionSpec(problem="toy_quad", tuner="annealing", arch="v5e",
                       budget=24, seed=4, workers=2, warm_start=warm)
    s1 = SessionStore(tmp_path / "a", clock=lambda: 0.0)
    s1.create(spec)
    full = run_session(spec, store=s1)
    s2 = SessionStore(tmp_path / "b", clock=lambda: 0.0)
    s2.create(spec)
    run_session(spec, store=s2, stop_after=7)
    resumed = resume_session(spec.session_id, s2)
    assert [t.config for t in full.trials] == [t.config for t in resumed.trials]


# --------------------------------------------------------------------- #
# screening seam
# --------------------------------------------------------------------- #
def test_screen_batch_split():
    prob, model = _model(n=120)
    screen = SurrogateScreen(model, prob.space, "v5e", measure_frac=0.25)
    rows = list(range(0, 160, 10))     # 16 candidates
    verdicts = screen.screen_rows(rows)
    measured = [i for i, v in enumerate(verdicts) if v is None]
    assert len(measured) == math.ceil(0.25 * len(rows))
    # the measured slice is the predicted-fastest one
    preds = model.predict_rows(prob.space, rows, "v5e")
    best = set(np.argsort(preds, kind="stable")[:len(measured)].tolist())
    assert set(measured) == best


def test_screen_estimated_trials_flagged():
    prob, model = _model(n=120)
    screen = SurrogateScreen(model, prob.space, "v5e", measure_frac=0.25)
    verdicts = screen.screen_rows(list(range(8)))
    est = [v for v in verdicts if v is not None]
    assert est
    for t in est:
        assert t.info == ESTIMATED_INFO
        assert t.info is not ESTIMATED_INFO    # own copy, never aliased
        assert t.valid and math.isfinite(t.objective)


def test_screen_singleton_threshold_and_max_defer():
    prob, model = _model(n=120)
    screen = SurrogateScreen(model, prob.space, "v5e",
                             measure_frac=0.25, max_defer=3)
    worst = prob.space.flat_index({f"p{i}": 7 for i in range(4)})
    outcomes = [screen.screen_rows([worst])[0] is None for _ in range(8)]
    # predicted-slow row: estimated until the defer cap forces a measure
    assert outcomes[:4] == [False, False, False, True]
    opt = prob.space.flat_index({f"p{i}": 2 for i in range(4)})
    assert screen.screen_rows([opt])[0] is None    # fast row: measured


def test_screen_wrong_arch_rejected():
    prob, model = _model(n=100)
    screen = SurrogateScreen(model, prob.space, "v5e")
    with pytest.raises(ValueError, match="calibrated for"):
        screen.screen_rows([1], "v4")


def test_screen_bad_measure_frac():
    prob, model = _model(n=100)
    with pytest.raises(ValueError, match="measure_frac"):
        SurrogateScreen(model, prob.space, "v5e", measure_frac=0.0)


def test_screened_session_journal_and_resume(tmp_path):
    """Provenance flags survive the journal: a screened session resumed
    from disk replays estimate-for-estimate, screen absent."""
    prob, model = _model(n=120)
    screen = SurrogateScreen(model, prob.space, "v5e", measure_frac=0.5)
    spec = SessionSpec(problem="toy_quad", tuner="genetic", arch="v5e",
                       budget=20, seed=6, workers=2)
    store = SessionStore(tmp_path / "s", clock=lambda: 0.0)
    store.create(spec)
    res = run_session(spec, store=store, screen=screen)
    est_idx = [i for i, t in enumerate(res.trials)
               if t.info.get("estimated")]
    assert est_idx and len(res.trials) == 20
    # journal records carry the provenance info verbatim
    journal = store.load_journal(spec.session_id, prob.space, "v5e")
    for i in est_idx:
        assert journal[i][1].info.get("provenance") == "surrogate-screen"
    # resume (no screen object anywhere): flags intact, trace identical
    resumed = resume_session(spec.session_id, store)
    assert [t.info.get("estimated") for t in resumed.trials] == \
        [t.info.get("estimated") for t in res.trials]
    assert [t.objective for t in resumed.trials] == \
        [t.objective for t in res.trials]


def test_screened_session_measures_fewer(tmp_path):
    prob, model = _model(n=120)
    screen = SurrogateScreen(model, prob.space, "v5e", measure_frac=0.25)
    spec = SessionSpec(problem="toy_quad", tuner="genetic", arch="v5e",
                       budget=32, seed=7, workers=2)
    res = run_session(spec, screen=screen)
    measured = sum(1 for t in res.trials if not t.info.get("estimated"))
    assert measured < len(res.trials)
    assert screen.n_estimated == len(res.trials) - measured


# --------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------- #
def _seed_store(tmp_path, archs=("v4", "v5e", "v5p"), budget=60):
    store_dir = tmp_path / "sessions"
    store = SessionStore(store_dir, clock=lambda: 0.0)
    for i, arch in enumerate(archs):
        spec = SessionSpec(problem="toy_quad", tuner="random", arch=arch,
                           budget=budget, seed=i, workers=2)
        store.create(spec)
        run_session(spec, store=store)
    return store_dir


def test_cli_surrogate_train_predict_eval(tmp_path, capsys):
    store_dir = _seed_store(tmp_path)
    models = str(tmp_path / "models")
    assert cli_main(["surrogate", "train", "--store", str(store_dir),
                     "--models", models, "--problem", "toy_quad",
                     "--params", json.dumps(SMALL), "--json"]) == 0
    report = json.loads(capsys.readouterr().out)
    assert report["report"][0]["trained"] is True
    assert cli_main(["surrogate", "predict", "--models", models,
                     "--problem", "toy_quad", "--arch", "v6e",
                     "--top", "4", "--json"]) == 0
    pred = json.loads(capsys.readouterr().out)
    assert len(pred["rows"]) == 4
    assert pred["predicted_s"] == sorted(pred["predicted_s"])
    assert cli_main(["surrogate", "eval", "--store", str(store_dir),
                     "--problem", "toy_quad", "--holdout", "v5p",
                     "--json"]) == 0
    ev = json.loads(capsys.readouterr().out)
    assert ev["transfers"] is True
    assert ev["r2_holdout"] > ev["r2_shuffled_baseline"]


def test_cli_train_too_few_rows(tmp_path, capsys):
    store_dir = tmp_path / "empty"
    SessionStore(store_dir)
    assert cli_main(["surrogate", "train", "--store", str(store_dir),
                     "--models", str(tmp_path / "m"),
                     "--problem", "toy_quad"]) == 1
    assert "not trained" in capsys.readouterr().out


def test_cli_predict_missing_model(tmp_path, capsys):
    assert cli_main(["surrogate", "predict",
                     "--models", str(tmp_path / "m"),
                     "--problem", "toy_quad"]) == 1
    assert "no usable model" in capsys.readouterr().err


def test_cli_submit_warm_start(tmp_path, capsys):
    store_dir = _seed_store(tmp_path)
    models = str(tmp_path / "models")
    # default (full-strength) params: the warm queue must rank the true
    # optimum into its top rows on the unseen arch
    cli_main(["surrogate", "train", "--store", str(store_dir),
              "--models", models, "--problem", "toy_quad"])
    capsys.readouterr()
    assert cli_main(["submit", "--problem", "toy_quad", "--tuner", "genetic",
                     "--arch", "v6e", "--budget", "16", "--seed", "0",
                     "--workers", "2", "--store", str(store_dir),
                     "--warm-start", models, "--warm-top", "4"]) == 0
    out = capsys.readouterr().out
    assert "warm start: 4 predicted-top rows" in out
    assert "best 1.0000s" in out       # optimum found inside the warm queue


def test_cli_subprocess_smoke(tmp_path):
    """The documented entry point exists out-of-process too."""
    env_src = str((tmp_path / "..").resolve())  # unused; keep env simple
    proc = subprocess.run(
        [sys.executable, "-m", "repro.orchestrator", "surrogate", "--help"],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/local/bin:/usr/bin:/bin"},
        cwd="/root/repo")
    assert proc.returncode == 0
    assert "train" in proc.stdout and "predict" in proc.stdout
