"""Telemetry layer: span tracing, fleet metrics, live views.

The load-bearing property sits in the middle of the file: trajectories
AND journal bytes are bit-identical with telemetry on vs off.  The
observability layer reads the tuning loop; it must never steer it.
"""

from __future__ import annotations

import json
import math
import threading
import time

import pytest

from repro import telemetry
from repro.telemetry import metrics as tmetrics
from repro.telemetry import trace as ttrace
from repro.telemetry.trace import span, traced, tracing


@pytest.fixture(autouse=True)
def _telemetry_off():
    """Every test starts and ends with clean, disabled telemetry."""
    telemetry.disable()
    ttrace.clear()
    tmetrics.reset()
    yield
    telemetry.disable()
    ttrace.clear()
    tmetrics.reset()


# --------------------------------------------------------------------- #
# span tracing
# --------------------------------------------------------------------- #
def test_spans_nest_and_record_depth():
    ttrace.enable()
    with span("outer", cat="t"):
        with span("inner", cat="t", n=3):
            pass
    evts = ttrace.events()
    # children close (and record) before parents
    assert [e["name"] for e in evts] == ["inner", "outer"]
    by = {e["name"]: e for e in evts}
    assert by["outer"]["depth"] == 0 and by["inner"]["depth"] == 1
    assert by["inner"]["args"] == {"n": 3}
    assert by["inner"]["dur"] <= by["outer"]["dur"]
    # timestamps are µs relative to the enable() origin
    assert by["outer"]["ts"] >= 0.0


def test_ring_buffer_keeps_newest():
    ttrace.enable(buffer=16)
    for i in range(50):
        with span(f"s{i}", cat="t"):
            pass
    evts = ttrace.events()
    assert len(evts) == 16
    assert [e["name"] for e in evts] == [f"s{i}" for i in range(34, 50)]


def test_disabled_span_is_shared_noop():
    assert not ttrace.is_enabled()
    s1 = span("a", cat="t")
    s2 = span("b", cat="t", n=1)
    assert s1 is s2                        # one shared null object, no alloc
    with s1:
        pass
    assert ttrace.events() == []


def test_traced_decorator_and_error_capture():
    ttrace.enable()

    @traced("work.step", cat="t")
    def step(x):
        return x + 1

    assert step(1) == 2

    with pytest.raises(ValueError):
        with span("boom", cat="t"):
            raise ValueError("nope")
    evts = {e["name"]: e for e in ttrace.events()}
    assert "work.step" in evts
    assert "ValueError" in evts["boom"]["args"]["error"]


def test_tracing_context_manager_restores_state():
    assert not ttrace.is_enabled()
    with tracing():
        assert ttrace.is_enabled()
        with span("inside", cat="t"):
            pass
        assert len(ttrace.events()) == 1
    assert not ttrace.is_enabled()


def test_thread_local_nesting():
    ttrace.enable()
    seen = []

    def worker():
        with span("child-thread", cat="t"):
            time.sleep(0.005)
        seen.append(True)

    with span("main-thread", cat="t"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    by = {e["name"]: e for e in ttrace.events()}
    # each thread nests independently: both are roots on their own stack
    assert by["main-thread"]["depth"] == 0
    assert by["child-thread"]["depth"] == 0
    assert by["main-thread"]["tid"] != by["child-thread"]["tid"]


def test_exports(tmp_path):
    ttrace.enable()
    with span("alpha", cat="t", n=1):
        with span("beta", cat="t"):
            pass
    jl = ttrace.export_jsonl(tmp_path / "t.jsonl")
    lines = jl.read_text().splitlines()
    header = json.loads(lines[0])
    assert header["trace"] == "repro.telemetry" and header["unit"] == "us"
    recs = [json.loads(x) for x in lines[1:]]
    assert {r["name"] for r in recs} == {"alpha", "beta"}
    ch = ttrace.export_chrome(tmp_path / "t.json")
    data = json.loads(ch.read_text())
    assert data["displayTimeUnit"] == "ms"
    assert len(data["traceEvents"]) == 2
    for e in data["traceEvents"]:
        assert e["ph"] == "X"
        assert {"name", "cat", "ts", "dur", "pid", "tid"} <= e.keys()


def test_summarize_orders_by_total():
    ttrace.enable()
    for _ in range(3):
        with span("quick", cat="t"):
            pass
    with span("slow", cat="t"):
        time.sleep(0.02)
    rows = ttrace.summarize(top=2)
    assert rows[0]["name"] == "slow"
    assert rows[1]["name"] == "quick" and rows[1]["count"] == 3
    assert rows[0]["total_ms"] >= rows[0]["max_ms"] >= rows[0]["mean_ms"]


# --------------------------------------------------------------------- #
# metrics registry
# --------------------------------------------------------------------- #
def test_metrics_instruments_and_labels():
    tmetrics.enable()
    tmetrics.counter("evals", session="a").inc(5)
    tmetrics.counter("evals", session="a").inc(2)
    tmetrics.counter("evals", session="b").inc()
    tmetrics.gauge("best", session="a").set(3.5)
    tmetrics.gauge("best", session="a").set(1.5)       # last write wins
    h = tmetrics.histogram("lat")
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    snap = {(s["name"], tuple(sorted(s["labels"].items()))): s
            for s in tmetrics.snapshot()}
    assert snap[("evals", (("session", "a"),))]["value"] == 7
    assert snap[("evals", (("session", "b"),))]["value"] == 1
    assert snap[("best", (("session", "a"),))]["value"] == 1.5
    hist = snap[("lat", ())]
    assert hist["count"] == 3 and hist["mean"] == pytest.approx(2.0)
    assert hist["min"] == 1.0 and hist["max"] == 3.0


def test_metrics_kind_mismatch_raises():
    tmetrics.enable()
    tmetrics.counter("x")
    with pytest.raises(TypeError):
        tmetrics.gauge("x")


def test_metrics_disabled_is_shared_noop():
    assert not tmetrics.is_enabled()
    a = tmetrics.counter("x", k="v")
    b = tmetrics.gauge("y")
    assert a is b                          # the one shared null instrument
    a.inc(10)
    b.set(5)
    tmetrics.enable()
    assert tmetrics.snapshot() == []       # nothing leaked through


def test_aggregate_samples():
    samples = [
        {"worker": "w1", "name": "evals", "value": 4, "kind": "counter"},
        {"worker": "w1", "name": "evals", "value": 6, "kind": "counter"},
        {"worker": "w1", "name": "rate", "value": 9.0, "kind": "gauge"},
        {"worker": "w1", "name": "rate", "value": 5.0, "kind": "gauge"},
        {"worker": "w2", "name": "evals", "value": 1, "kind": "counter"},
    ]
    agg = tmetrics.aggregate_samples(samples)
    assert agg == {"w1": {"evals": 10.0, "rate": 5.0},
                   "w2": {"evals": 1.0}}


def test_fleet_snapshot_from_memory_broker():
    from repro.orchestrator import MemoryBroker
    from repro.orchestrator.queue import LEASED, PENDING

    b = MemoryBroker()
    b.submit({"problem": "toy_quad", "archs": ["v5e"], "rows": [1],
              "sessions": []})
    b.submit({"problem": "toy_quad", "archs": ["v5e"], "rows": [2],
              "sessions": []})
    b.lease("w-ok", lease_s=30.0)
    b.record_metrics("w-ok", [
        {"name": "evals", "value": 10, "kind": "counter"},
        {"name": "eval_s", "value": 2.0, "kind": "counter"}])
    snap = tmetrics.fleet_snapshot(b)
    assert snap["queue"][PENDING] == 1 and snap["queue"][LEASED] == 1
    w = snap["workers"]["w-ok"]
    assert w["leases"] == 1 and w["stale"] is False
    assert w["heartbeat_age"] >= 0.0
    assert w["evals"] == 10.0
    # derived when the worker never set the gauge: evals / eval_s
    assert w["configs_per_s"] == pytest.approx(5.0)
    # a pure read: nothing was reaped or requeued
    assert b.counts()[LEASED] == 1


def test_memory_broker_jsonl_sink(tmp_path):
    from repro.orchestrator import MemoryBroker

    sink = tmp_path / "metrics.jsonl"
    b = MemoryBroker(metrics_sink=sink)
    b.record_metrics("w1", [{"name": "jobs", "value": 1,
                             "kind": "counter"}])
    b.record_metrics("w1", [{"name": "jobs", "value": 1,
                             "kind": "counter"}])
    recs = [json.loads(x) for x in sink.read_text().splitlines()]
    assert len(recs) == 2
    assert all(r["worker"] == "w1" and r["name"] == "jobs" for r in recs)
    assert recs[0]["ts"] <= recs[1]["ts"]


# --------------------------------------------------------------------- #
# the invariant: telemetry reads the loop, never steers it
# --------------------------------------------------------------------- #
def test_trajectory_and_journal_bit_identical_on_vs_off(tmp_path):
    from repro.orchestrator import SessionSpec, SessionStore, run_session

    spec = SessionSpec(problem="toy_rastrigin", tuner="genetic", budget=48,
                       seed=11, workers=2)

    def run(tag, on):
        store = SessionStore(tmp_path / tag)
        if on:
            telemetry.enable()
        else:
            telemetry.disable()
        res = run_session(spec, store=store)
        telemetry.disable()
        return (res, store._journal_path(spec.session_id).read_bytes())

    res_off, j_off = run("off", on=False)
    res_on, j_on = run("on", on=True)
    assert [t.config for t in res_off.trials] == \
           [t.config for t in res_on.trials]
    assert [t.objective for t in res_off.trials] == \
           [t.objective for t in res_on.trials]
    assert j_off == j_on


def test_session_spans_and_metrics_land():
    from repro.orchestrator import SessionSpec, run_session

    telemetry.enable()
    spec = SessionSpec(problem="toy_quad", tuner="random", budget=12,
                       seed=0, workers=2)
    res = run_session(spec)
    names = {e["name"] for e in ttrace.events()}
    assert {"session.ask", "session.tell", "pool.evaluate",
            "pool.chunk"} <= names
    snap = {(s["name"], dict(s["labels"]).get("session")): s["value"]
            for s in tmetrics.snapshot()}
    sid = spec.session_id
    assert snap[("session.evals", sid)] == len(res.trials)
    assert snap[("session.best", sid)] == res.best.objective
    assert 1 <= snap[("session.evals_to_best", sid)] <= len(res.trials)


def test_measured_problem_records_build_measure_split():
    from repro.core.problem import MeasuredProblem
    from repro.core.space import Param, SearchSpace

    space = SearchSpace([Param("a", (1, 2))], name="m")
    prob = MeasuredProblem(space, build=lambda cfg: (lambda: None),
                           repeats=2, warmup=0)
    ttrace.enable()
    t = prob.evaluate({"a": 1}, arch="cpu")
    assert t.valid
    by = {e["name"]: e for e in ttrace.events()}
    assert by["kernel.build"]["cat"] == "kernel"
    assert by["kernel.measure"]["args"]["repeats"] == 2


# --------------------------------------------------------------------- #
# live views (CLI)
# --------------------------------------------------------------------- #
def _make_session(tmp_path):
    from repro.orchestrator import SessionSpec, SessionStore, run_session

    store = SessionStore(tmp_path / "store")
    spec = SessionSpec(problem="toy_quad", tuner="random", budget=12,
                       seed=0, workers=2)
    run_session(spec, store=store)
    return store, spec.session_id


def test_cli_status_json(tmp_path, capsys):
    from repro.orchestrator.cli import main as cli_main

    store, sid = _make_session(tmp_path)
    rc = cli_main(["status", "--store", str(store.root), "--json"])
    assert rc == 0
    lines = capsys.readouterr().out.strip().splitlines()
    rows = [json.loads(x) for x in lines]
    assert len(rows) == 1
    row = rows[0]
    assert row["session"] == sid and row["status"] == "done"
    assert row["evaluated"] == 12 and row["budget"] == 12
    assert isinstance(row["best"], float) and math.isfinite(row["best"])


def test_cli_status_watch_renders_frames(tmp_path, capsys):
    from repro.orchestrator.cli import main as cli_main

    store, sid = _make_session(tmp_path)
    rc = cli_main(["status", "--store", str(store.root), "--watch",
                   "--count", "2", "--interval", "0.01"])
    assert rc == 0
    out = capsys.readouterr().out
    assert out.count("\x1b[2J") == 2               # one clear per frame
    assert sid in out
    assert "[" in out and "12/12" in out           # progress bar
    assert any(c in out for c in "▁▂▃▄▅▆▇█")       # best-so-far sparkline


def test_cli_metrics_dump_and_raw(tmp_path, capsys):
    from repro.orchestrator import SQLiteBroker
    from repro.orchestrator.cli import main as cli_main

    db = tmp_path / "queue.db"
    b = SQLiteBroker(db)
    b.record_metrics("w1", [
        {"name": "jobs", "value": 2, "kind": "counter"},
        {"name": "evals", "value": 40, "kind": "counter"},
        {"name": "eval_s", "value": 4.0, "kind": "counter"}])
    b.close()

    rc = cli_main(["metrics", "--broker", str(db)])
    assert rc == 0
    snap = json.loads(capsys.readouterr().out)
    assert snap["workers"]["w1"]["evals"] == 40.0
    assert snap["workers"]["w1"]["configs_per_s"] == pytest.approx(10.0)
    assert "queue" in snap

    rc = cli_main(["metrics", "--broker", str(db), "--raw"])
    assert rc == 0
    recs = [json.loads(x)
            for x in capsys.readouterr().out.strip().splitlines()]
    assert {r["name"] for r in recs} == {"jobs", "evals", "eval_s"}


def test_cli_metrics_refuses_missing_db(tmp_path, capsys):
    from repro.orchestrator.cli import main as cli_main

    missing = tmp_path / "nope" / "queue.db"
    rc = cli_main(["metrics", "--broker", str(missing)])
    assert rc == 2
    assert "no broker db" in capsys.readouterr().err
    assert not missing.exists()


def test_fmt_age_humanizes():
    from repro.orchestrator.cli import _fmt_age

    assert _fmt_age(3.21) == "3.2s"
    assert _fmt_age(0.0) == "0.0s"
    assert _fmt_age(245) == "4.1m"
    assert _fmt_age(9000) == "2.5h"


def test_cli_trace_flag_exports_chrome(tmp_path, capsys):
    from repro.orchestrator.cli import main as cli_main

    out = tmp_path / "trace.json"
    rc = cli_main(["submit", "--problem", "toy_quad", "--tuner", "random",
                   "--budget", "8", "--store", str(tmp_path / "store"),
                   "--trace", str(out)])
    assert rc == 0
    data = json.loads(out.read_text())
    names = {e["name"] for e in data["traceEvents"]}
    assert {"session.ask", "session.tell"} <= names
    assert not ttrace.is_enabled()         # the flag's enable was scoped
