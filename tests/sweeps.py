"""Property-style sweep harness (hypothesis is not installable offline).

``sweep(n)(f)`` runs ``f(rng)`` for ``n`` independent seeded RNGs; on the
first failure it re-raises with the failing seed in the message so the case
is reproducible with ``rng = random.Random(seed)``.  ``f`` generates its own
random case from the rng — same generate-check loop as a property test,
minus shrinking.
"""

from __future__ import annotations

import random

BASE_SEED = 20230701


def sweep(n: int = 50, base_seed: int = BASE_SEED):
    def deco(f):
        # NOTE: no functools.wraps — pytest must not see ``rng`` in the
        # wrapper's signature (it would look like a fixture).
        def wrapper():
            for i in range(n):
                seed = base_seed + i
                try:
                    f(random.Random(seed))
                except AssertionError as e:
                    raise AssertionError(
                        f"[sweep seed={seed} case={i}/{n}] {e}") from e
        wrapper.__name__ = f.__name__
        wrapper.__doc__ = f.__doc__
        return wrapper
    return deco


def random_subspace(rng: random.Random, max_params: int = 5,
                    max_vals: int = 6, constrained: bool = True):
    """A random small SearchSpace (optionally with a random constraint)."""
    from repro.core.space import Constraint, Param, SearchSpace

    n_params = rng.randint(1, max_params)
    params = []
    for i in range(n_params):
        k = rng.randint(2, max_vals)
        vals = rng.sample(range(1, 64), k)
        params.append(Param(f"p{i}", tuple(vals)))
    constraints = []
    if constrained and n_params >= 2 and rng.random() < 0.7:
        a, b = rng.sample(range(n_params), 2)

        def fn(cfg, a=a, b=b):
            return (cfg[f"p{a}"] + cfg[f"p{b}"]) % 2 == 0

        constraints.append(Constraint("parity", fn))
    return SearchSpace(params, constraints, name="rand")
